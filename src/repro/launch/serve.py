"""Serving launcher: stand up the QA reranking service on any backend.

  # paper-faithful single-threaded server
  PYTHONPATH=src python -m repro.launch.serve --backend aot --port 9090

  # concurrent cluster: 4 replicas behind a thread-pool server with
  # power-of-two-choices routing and a bounded admission queue
  PYTHONPATH=src python -m repro.launch.serve --server threadpool \
      --replicas 4 --policy p2c --max-queue 256 --port 9090

  # print how the canonical ranking pipeline lowers to each execution plan
  PYTHONPATH=src python -m repro.launch.serve --describe

  # multi-process fabric: 4 pipeline-serving worker processes behind a
  # health-probed hedging router (serving.fabric), supervised until ^C
  PYTHONPATH=src python -m repro.launch.serve --fabric 4 --backend numpy

  # ask a running server to drain gracefully (finish in-flight, shed new)
  PYTHONPATH=src python -m repro.launch.serve --drain 127.0.0.1:9090

  # version-bound serving from a model registry (core.registry), with
  # live hot-swap / shadow / A-B (serving.rollout; see docs/rollout.md):
  PYTHONPATH=src python -m repro.launch.serve --serve-pipeline \
      --registry /tmp/registry --model-version latest --port 9090
  PYTHONPATH=src python -m repro.launch.serve --swap v-0123abcd --port 9090
  PYTHONPATH=src python -m repro.launch.serve --serve-pipeline \
      --registry /tmp/registry --shadow v-0123abcd --shadow-fraction 0.2
  PYTHONPATH=src python -m repro.launch.serve --serve-pipeline \
      --registry /tmp/registry --ab v-0123abcd:25

  # serve the WHOLE multi-stage pipeline behind one RPC (wire v3
  # MSG_RANK / MSG_RANK_BATCH; drive with Client.rank / rank_batch or a
  # plan(pipeline, "remote_pipeline", ctx) on the client side)
  PYTHONPATH=src python -m repro.launch.serve --serve-pipeline \
      --server threadpool --backend jit --port 9090

  (then drive it with repro.core.service.Client, benchmarks/loadgen.py,
  or examples/serve_pipeline.py; --hedge-ms sets the fixed hedge delay
  clients of THIS process's plans use when ctx.remote lists several
  endpoints — 0 keeps the adaptive p95 delay)

Single-server scorer construction routes through the declarative pipeline
API's ``PlanContext`` (repro.core.plan), the same factory the planner and
examples use; replica pools still build one independent scorer per replica
(``ReplicaPool.build``) so replicas don't share compiled-function state.
"""
from __future__ import annotations

import argparse
import time

from repro.launch.world import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan
from repro.serving.admission import AdmissionController
from repro.serving.cluster import POLICIES, ReplicaPool


def canonical_pipeline(backend: str):
    """The demo cascade every launcher entry point serves/describes."""
    return (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
            >> ops.Rerank(backend, k=3))


def _wrap_rollout(args, engine, ctx, target: str):
    """Wrap the primary engine in shadow / A-B layers (serving.rollout)
    when requested. Candidate arms are full ``PipelineEngine``s planned
    against a version-rebound context, so they never share compiled
    scorers with the primary."""
    shadow = getattr(args, "shadow", None)
    ab = getattr(args, "ab", None)
    if not shadow and not ab:
        return engine
    if target == "remote":
        raise SystemExit("--shadow/--ab need an in-process candidate plan; "
                         "use --plan-target local|batched (the remote "
                         "target's ReplicaPool would be shared by both "
                         "versions)")
    from repro.serving.engine import PipelineEngine
    from repro.serving.rollout import ABEngine, ShadowEngine
    if ab:
        version, _, pct = ab.partition(":")
        arm_b = PipelineEngine(canonical_pipeline(args.backend),
                               ctx.bind_version(version), target=target)
        engine = ABEngine(engine, arm_b,
                          split_pct=float(pct) if pct else 50.0)
    if shadow:
        candidate = PipelineEngine(canonical_pipeline(args.backend),
                                   ctx.bind_version(shadow), target=target)
        engine = ShadowEngine(engine, candidate,
                              fraction=getattr(args, "shadow_fraction",
                                               0.2))
    return engine


def build_server(args, cfg, params, corpus, tok, index=None, ctx=None):
    """Build (server, pool-or-None) from parsed CLI args."""
    if ctx is None:
        registry = None
        if getattr(args, "registry", None):
            from repro.core.registry import ModelRegistry
            registry = ModelRegistry(args.registry)
        model_version = getattr(args, "model_version", None)
        if model_version and registry is None:
            raise SystemExit("--model-version needs --registry DIR")
        ctx = PlanContext.from_world(cfg, params, corpus, tok, index=index,
                                     buckets=(1, 8, 64, 256),
                                     hedge_ms=getattr(args, "hedge_ms",
                                                      None),
                                     registry=registry,
                                     model_version=model_version)
    if getattr(args, "serve_pipeline", False):
        # Whole-pipeline ranking service (wire v3): the handler lowers the
        # canonical pipeline server-side and answers MSG_RANK_BATCH with
        # ranked lists — one RPC per query batch instead of pair scoring.
        from repro.serving.engine import PipelineEngine
        target = getattr(args, "plan_target", "batched")
        pool = None
        if target == "remote":
            # Rerank stages dispatch through an in-process ReplicaPool
            # (MicroBatcher + replica scorers) instead of calling the
            # scorer inline — so each worker process exercises, and
            # reports telemetry for, the full admission -> batcher ->
            # scorer path (queue-wait vs compute histograms per worker).
            import dataclasses as _dc
            # ctx.params, not the raw build_world params: a --model-version
            # bind already resolved registry weights into the context.
            pool = ReplicaPool.build(args.backend, ctx.params, cfg, tok,
                                     corpus.idf, n_replicas=args.replicas,
                                     buckets=ctx.buckets or (1, 8, 64, 256),
                                     policy=args.policy)
            pool.model_version = getattr(ctx, "model_version", None)
            ctx = _dc.replace(ctx, remote=pool)
        engine = PipelineEngine(canonical_pipeline(args.backend), ctx,
                                target=target)
        engine = _wrap_rollout(args, engine, ctx, target)
        if args.server == "simple":
            return SV.SimpleServer(engine, host=args.host,
                                   port=args.port), pool
        # Ranking requests are sized at len(queries) x rows_per_query, so
        # the bound must cover a realistic query batch (one plan.run_many
        # is ONE RPC) — auto-raise to a 32-query batch; clients driving
        # bigger batches chunk with PlanContext.rank_chunk.
        admission = (AdmissionController(max_queue_rows=max(
                         args.max_queue, engine.rows_per_query * 32))
                     if args.max_queue > 0 else None)
        return SV.ThreadPoolServer(engine, host=args.host, port=args.port,
                                   num_workers=args.workers,
                                   admission=admission), pool
    if args.server == "simple":
        scorer = ctx.scorer_for(args.backend)
        handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                              cfg.max_len)
        return SV.SimpleServer(handler, host=args.host, port=args.port), None
    pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                             n_replicas=args.replicas,
                             buckets=ctx.buckets or (1, 8, 64, 256),
                             policy=args.policy)
    admission = (AdmissionController(max_queue_rows=args.max_queue)
                 if args.max_queue > 0 else None)
    srv = SV.ThreadPoolServer(pool, host=args.host, port=args.port,
                              num_workers=args.workers, admission=admission)
    return srv, pool


class _Unconnected:
    """Placeholder remote endpoint: lowers but refuses to score."""

    def get_score_batch(self, pairs):
        raise RuntimeError("no server connected (--describe only lowers)")

    def rank_batch(self, queries):
        raise RuntimeError("no server connected (--describe only lowers)")


def describe_plans(args, cfg, params, corpus, tok, index) -> str:
    """The canonical pipeline, lowered to every execution target."""
    pipeline = canonical_pipeline(args.backend)
    ctx = PlanContext.from_world(cfg, params, corpus, tok, index,
                                 remote=_Unconnected(),
                                 hedge_ms=getattr(args, "hedge_ms", None))
    lines = [f"pipeline: {pipeline!r}"]
    for target in ("local", "batched", "remote", "remote_pipeline"):
        lines.append("  " + plan(pipeline, target, ctx).describe())
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--server", default="simple",
                    choices=["simple", "threadpool"],
                    help="simple = paper's TSimpleServer; threadpool = "
                         "concurrent worker pool over a replica cluster")
    ap.add_argument("--replicas", type=int, default=2,
                    help="scorer replicas behind the threadpool server")
    ap.add_argument("--policy", default="least_outstanding",
                    choices=list(POLICIES), help="replica routing policy")
    ap.add_argument("--max-queue", type=int, default=512,
                    help="admission bound on outstanding rows "
                         "(0 disables admission control)")
    ap.add_argument("--workers", type=int, default=8,
                    help="threadpool connection workers")
    ap.add_argument("--describe", action="store_true",
                    help="print the canonical pipeline lowered to every "
                         "execution plan, then exit")
    ap.add_argument("--serve-pipeline", action="store_true",
                    help="serve the WHOLE canonical multi-stage pipeline "
                         "behind wire v3 ranking RPCs (MSG_RANK / "
                         "MSG_RANK_BATCH) instead of pair scoring")
    ap.add_argument("--plan-target", default="batched",
                    choices=["local", "batched", "remote"],
                    help="execution plan for --serve-pipeline; 'remote' "
                         "routes rerank through an in-process ReplicaPool "
                         "(MicroBatcher + replicas), so this process "
                         "reports batcher queue-wait/compute telemetry")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="on shutdown, export this process's finished "
                         "spans as Chrome trace-event JSON (load in "
                         "Perfetto / chrome://tracing)")
    ap.add_argument("--hedge-ms", type=float, default=None,
                    help="fixed hedge delay (ms) for plans whose "
                         "ctx.remote lists several endpoints; default "
                         "adapts to the observed p95")
    ap.add_argument("--fabric", type=int, default=0, metavar="N",
                    help="spawn N pipeline-serving worker PROCESSES "
                         "behind a health-probed hedging router "
                         "(serving.fabric) and supervise until ^C")
    ap.add_argument("--drain", default=None, metavar="HOST:PORT",
                    help="send MSG_DRAIN to a running server (finish "
                         "in-flight, shed new work), print its health "
                         "snapshot, and exit")
    ap.add_argument("--registry", default=None, metavar="DIR",
                    help="model registry directory (core.registry): "
                         "enables --model-version binding and live "
                         "MSG_SWAP hot-swaps on this server")
    ap.add_argument("--model-version", default=None, metavar="VID",
                    help="serve this registry version ('latest', a full "
                         "id, or a unique prefix) instead of the "
                         "freshly-trained params; needs --registry")
    ap.add_argument("--swap", default=None, metavar="VERSION",
                    help="client command: hot-swap a RUNNING server "
                         "(--host/--port) to this registry version over "
                         "MSG_SWAP, print the reply, and exit")
    ap.add_argument("--shadow", default=None, metavar="VERSION",
                    help="mirror a sampled fraction of ranking traffic "
                         "to this registry version and record divergence "
                         "metrics; candidate rankings are discarded "
                         "(needs --serve-pipeline + --registry)")
    ap.add_argument("--shadow-fraction", type=float, default=0.2,
                    help="fraction of distinct queries mirrored by "
                         "--shadow (deterministic hash sampling)")
    ap.add_argument("--ab", default=None, metavar="VERSION[:PCT]",
                    help="A/B split: route PCT%% (default 50) of the "
                         "query hash space to this registry version; "
                         "per-arm metrics carry model_version labels "
                         "(needs --serve-pipeline + --registry)")
    args = ap.parse_args()

    if args.swap:
        if args.port == 0:
            raise SystemExit("--swap is a client command: point it at a "
                             "running server with --host/--port")
        with SV.Client((args.host, args.port)) as client:
            vid, status = client.swap(args.swap)
        print(f"swap acknowledged: version={vid} status={status}")
        return

    if args.drain:
        host, _, port = args.drain.rpartition(":")
        with SV.Client((host or "127.0.0.1", int(port))) as client:
            snap = client.drain()
        print("drain acknowledged: " + " ".join(
            f"{k}={v:g}" for k, v in sorted(snap.items())))
        return
    if args.fabric > 0:
        # The supervisor builds no world of its own — each worker process
        # trains/compiles independently (that is the point of the fabric).
        from repro.serving.fabric import Fabric
        extra = []
        if args.plan_target != "batched":
            extra += ["--plan-target", args.plan_target]
        if args.registry:
            extra += ["--registry", args.registry]
        if args.model_version:
            extra += ["--model-version", args.model_version]
        with Fabric(n_workers=args.fabric, backend=args.backend,
                    train_steps=args.train_steps, server="threadpool",
                    worker_threads=args.workers,
                    max_queue=args.max_queue, extra_args=extra) as fab:
            for w in fab.workers:
                print(f"fabric worker {w.slot} (pid {w.proc.pid}) "
                      f"on {w.address}")
            print(f"fabric up: {args.fabric} workers, router probing "
                  f"health; ^C to tear down", flush=True)
            try:
                while True:
                    time.sleep(1.0)
            except KeyboardInterrupt:
                pass
        return

    cfg, params, corpus, tok, index, _ = build_world(args.train_steps)
    if args.describe:
        print(describe_plans(args, cfg, params, corpus, tok, index))
        return
    srv, pool = build_server(args, cfg, params, corpus, tok, index=index)
    mode = (f"{args.server}" if args.server == "simple" else
            f"{args.server} x{args.replicas} {args.policy} "
            f"max_queue={args.max_queue}")
    if args.serve_pipeline:
        mode += " serve-pipeline(rank-rpc)"
    print(f"serving QuestionAnswering ({args.backend}, {mode}) "
          f"on {srv.address}")
    # Machine-readable discovery line for the fabric supervisor: workers
    # bind port 0, so this flushed line is how serving.fabric learns the
    # address (stdout is a PIPE there — without flush=True the line sits
    # in the child's block buffer and the supervisor times out waiting).
    host, port = srv.address[0], srv.address[1]
    print(f"FABRIC_READY {host} {port}", flush=True)
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        srv.stop()
        if pool is not None:
            pool.stop()
        if args.trace_out:
            from repro.serving import telemetry
            n = telemetry.export_chrome_trace(
                args.trace_out, telemetry.get_tracer().finished())
            print(f"wrote {n} trace events to {args.trace_out}")


if __name__ == "__main__":
    main()
