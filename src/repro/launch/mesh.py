"""Production mesh entry point (re-export; see repro.distributed.mesh)."""
from repro.distributed.mesh import (axis_size, data_axes, make_mesh,  # noqa: F401
                                    make_production_mesh)
