"""Training launcher: ``--arch <id>`` selects any registered architecture.

Runs REDUCED configs end-to-end on this host (full configs are exercised via
launch.dryrun; on a real pod the same code path runs them by passing
--full). Includes checkpoint/resume, straggler accounting, and the
fault-tolerant step loop.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --steps 30
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-mlperf --steps 50
"""
from __future__ import annotations

import argparse
import functools

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config, reduced
from repro.training.optimizer import adamw, warmup_cosine_schedule
from repro.training.train_loop import Trainer


def build(arch: str, full: bool, batch: int, seq_len: int):
    cfg = get_config(arch)
    if not full:
        cfg = reduced(cfg)
    fam = cfg.family
    key = jax.random.PRNGKey(0)

    if fam == "lm":
        from repro.data.lm import token_batches
        from repro.models import transformer as tfm
        params = tfm.init_lm(key, cfg)
        loss = functools.partial(tfm.loss_fn, cfg=cfg)
        data = token_batches(cfg.vocab_size, batch, seq_len)
        return cfg, params, loss, data

    if fam == "gnn":
        from repro.data.graph import graph_batch
        from repro.models import gnn as gnn_lib
        d_feat = 16
        params = gnn_lib.init_gnn(key, cfg, d_feat)
        loss = functools.partial(gnn_lib.loss_fn, cfg=cfg)

        def graphs():
            i = 0
            while True:
                yield graph_batch(200, 800, d_feat=d_feat, d_out=cfg.d_out,
                                  seed=i)
                i += 1
        return cfg, params, loss, graphs()

    if fam == "recsys":
        from repro.data.recsys import batches
        from repro.models import recsys as rec_lib
        params = rec_lib.init_model(key, cfg)
        loss = functools.partial(rec_lib.loss_fn, cfg=cfg)
        return cfg, params, loss, batches(cfg, batch)

    # textpair (sm-cnn)
    from repro.data import qa as QA
    from repro.data.tokenizer import HashingTokenizer
    from repro.models import sm_cnn
    corpus = QA.generate_corpus(n_docs=80, n_questions=60, seed=0)
    tok = HashingTokenizer(cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(key, cfg)
    loss = functools.partial(sm_cnn.loss_fn, cfg=cfg)

    def pairs():
        ep = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, batch, seed=ep)
            ep += 1
    return cfg, params, loss, pairs()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=list(ASSIGNED_ARCHS) + ["sm-cnn"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; use under a real mesh)")
    args = ap.parse_args()

    cfg, params, loss, data = build(args.arch, args.full, args.batch,
                                    args.seq_len)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={args.arch} family={cfg.family} params={n_params:,}")
    opt = adamw(warmup_cosine_schedule(args.lr, 10, args.steps))
    tr = Trainer(loss, opt, params, ckpt_dir=args.ckpt_dir, ckpt_every=50)
    if args.ckpt_dir and tr.restore():
        print(f"resumed at step {tr.step}")
    metrics = tr.run(data, max_steps=args.steps, log_every=10)
    print("final:", {k: round(v, 4) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
