"""One declarative pipeline, four execution plans — throughput comparison.

The core claim of the pipeline-algebra redesign: a single description

    Retrieve(h=10) >> Rerank(backend, k=5)

executes under the ``local`` (sequential per-query), ``batched``
(cross-query coalesced), ``remote`` (rerank pairs dispatched through the
RPC serving cluster: ``ThreadPoolServer`` over a 2-replica ``ReplicaPool``,
driven by a ``service.Client``), and ``remote_pipeline`` (the WHOLE cascade
served behind one wire-v3 ranking RPC per query batch by a
``PipelineEngine`` handler) plans with identical rankings, while the
batched plan keeps its ~3-5x throughput advantage over the local plan and
the ranking RPC beats the per-pair remote plan (query strings cross the
wire instead of every candidate pair).

Protocol: every plan gets a fresh context (plans from one context share a
featurization cache), warms on queries disjoint from the measured 32-query
batch, is measured cold, and the rankings are cross-checked afterwards
(``verify_plans`` — checking after the timed run keeps the server-side
caches cold for the remote measurement).

  PYTHONPATH=src python -m benchmarks.pipeline_plans
  PYTHONPATH=src python -m benchmarks.run --table pipeline_plans --json out.json
"""
from __future__ import annotations

import gc
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan, verify_plans

BATCH = 32


def run(world=None, backend: str = "jit", n_queries: int = 60) -> List[Dict]:
    cfg, params, corpus, tok, index, _ = world or build_world()
    queries = corpus.questions[:n_queries]
    measured, warm = queries[:BATCH], queries[BATCH:]

    scorer = BK.make_scorer(backend, params, cfg, buckets=(64, 256, 1024))
    for b in (64, 256, 1024):           # precompile: no jit in timed loops
        scorer(np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, 4), np.float32))
    pipeline = ops.Retrieve(h=10) >> ops.Rerank(scorer, k=5)

    # remote execution substrate: threadpool server over a replica pool
    from repro.serving.cluster import ReplicaPool
    from repro.serving.engine import PipelineEngine
    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(64, 256, 1024),
                             policy="least_outstanding")
    srv = SV.ThreadPoolServer(pool).start_background()

    # remote_pipeline substrate: the same cascade served whole behind the
    # v3 ranking RPC. The engine's rerank dispatches into its OWN 2-replica
    # pool (in-process, same chunk size as the pair plan's RPCs), so remote
    # and remote_pipeline run the exact same scoring substrate and the
    # measured difference is purely the RPC boundary: one ranking RPC per
    # query batch vs ~5 chunked pair RPCs shipping every candidate string.
    # (A separate pool, not `pool`: sharing would let the pair plan's
    # measurement warm the ranking server's featurization cache.)
    rank_pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                                  n_replicas=2, buckets=(64, 256, 1024),
                                  policy="least_outstanding")
    engine = PipelineEngine(
        pipeline, PlanContext.from_world(cfg, params, corpus, tok, index,
                                         remote=rank_pool),
        target="remote")
    rank_srv = SV.ThreadPoolServer(engine).start_background()

    def fresh_ctx(remote) -> PlanContext:
        # one context (so one featurization cache) per plan: a shared cache
        # would let the first measured plan warm the later ones
        return PlanContext.from_world(cfg, params, corpus, tok, index,
                                      remote=remote)

    plans = {t: plan(pipeline, t, fresh_ctx(srv.address))
             for t in ("local", "batched", "remote")}
    plans["remote_pipeline"] = plan(pipeline, "remote_pipeline",
                                    fresh_ctx(rank_srv.address))
    rows: List[Dict] = []
    timings: Dict[str, float] = {}
    try:
        for name, p in plans.items():
            p.run_many(warm)            # disjoint warm-up: compiled entries
            gc.collect()                # pay the accumulated allocation
            # debt NOW: otherwise one arbitrary plan (whichever is measured
            # when the gen-2 threshold trips) eats a ~60ms GC pause
            t0 = time.perf_counter()    # + caches never see measured pairs
            if name == "local":
                for q in measured:
                    p.run(q)
            else:
                p.run_many(measured)
            timings[name] = time.perf_counter() - t0
        verify_plans(list(plans.values()), measured[:8])
    finally:
        for p in plans.values():
            p.close()
        srv.stop()
        pool.stop()
        rank_srv.stop()
        rank_pool.stop()

    for name, dt in timings.items():
        derived = f"qps={len(measured) / dt:.1f}"
        if name != "local":
            derived += f" speedup={timings['local'] / dt:.2f}x"
        if name == "remote_pipeline":
            # the acceptance metric: one ranking RPC per query batch vs the
            # per-pair remote plan's chunked pair RPCs
            derived += f" vs_pair_rpc={timings['remote'] / dt:.2f}x"
        rows.append({"name": f"pipeline_plans/{backend}-{name}",
                     "us_per_call": 1e6 * dt / len(measured),
                     "derived": derived})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
