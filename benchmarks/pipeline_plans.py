"""One declarative pipeline, three execution plans — throughput comparison.

The core claim of the pipeline-algebra redesign: a single description

    Retrieve(h=10) >> Rerank(backend, k=5)

executes under the ``local`` (sequential per-query), ``batched``
(cross-query coalesced), and ``remote`` (rerank dispatched through the RPC
serving cluster: ``ThreadPoolServer`` over a 2-replica ``ReplicaPool``,
driven by a ``service.Client``) plans with identical rankings, while the
batched plan keeps its ~3-5x throughput advantage over the local plan.

Protocol: every plan gets a fresh context (plans from one context share a
featurization cache), warms on queries disjoint from the measured 32-query
batch, is measured cold, and the rankings are cross-checked afterwards
(``verify_plans`` — checking after the timed run keeps the server-side
caches cold for the remote measurement).

  PYTHONPATH=src python -m benchmarks.pipeline_plans
  PYTHONPATH=src python -m benchmarks.run --table pipeline_plans --json out.json
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan, verify_plans

BATCH = 32


def run(world=None, backend: str = "jit", n_queries: int = 60) -> List[Dict]:
    cfg, params, corpus, tok, index, _ = world or build_world()
    queries = corpus.questions[:n_queries]
    measured, warm = queries[:BATCH], queries[BATCH:]

    scorer = BK.make_scorer(backend, params, cfg, buckets=(64, 256, 1024))
    for b in (64, 256, 1024):           # precompile: no jit in timed loops
        scorer(np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, 4), np.float32))
    pipeline = ops.Retrieve(h=10) >> ops.Rerank(scorer, k=5)

    # remote execution substrate: threadpool server over a replica pool
    from repro.serving.cluster import ReplicaPool
    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(64, 256, 1024),
                             policy="least_outstanding")
    srv = SV.ThreadPoolServer(pool).start_background()

    def fresh_ctx() -> PlanContext:
        # one context (so one featurization cache) per plan: a shared cache
        # would let the first measured plan warm the later ones
        return PlanContext.from_world(cfg, params, corpus, tok, index,
                                      remote=srv.address)

    plans = {t: plan(pipeline, t, fresh_ctx())
             for t in ("local", "batched", "remote")}
    rows: List[Dict] = []
    timings: Dict[str, float] = {}
    try:
        for name, p in plans.items():
            p.run_many(warm)            # disjoint warm-up: compiled entries
            t0 = time.perf_counter()    # + caches never see measured pairs
            if name == "local":
                for q in measured:
                    p.run(q)
            else:
                p.run_many(measured)
            timings[name] = time.perf_counter() - t0
        verify_plans(list(plans.values()), measured[:8])
    finally:
        for p in plans.values():
            p.close()
        srv.stop()
        pool.stop()

    for name, dt in timings.items():
        derived = f"qps={len(measured) / dt:.1f}"
        if name != "local":
            derived += f" speedup={timings['local'] / dt:.2f}x"
        rows.append({"name": f"pipeline_plans/{backend}-{name}",
                     "us_per_call": 1e6 * dt / len(measured),
                     "derived": derived})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
