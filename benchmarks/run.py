"""Benchmark orchestrator. One function per paper table; prints
``name,us_per_call,derived`` CSV and can dump the full rows as JSON so the
perf trajectory is machine-readable across PRs.

  PYTHONPATH=src python -m benchmarks.run                # all tables, quick
  PYTHONPATH=src python -m benchmarks.run --table 1      # just Table 1
  PYTHONPATH=src python -m benchmarks.run --table loadgen --json out.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import time


def snapshot_meta() -> dict:
    """Provenance stamped onto every JSON row: which commit, when, and on
    how many cores the numbers were taken — so two BENCH_*.json files are
    comparable (or visibly not, e.g. different host_cores)."""
    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10,
                             cwd=os.path.dirname(os.path.abspath(__file__)),
                             ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    return {
        "git_sha": sha,
        "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "host_cores": float(os.cpu_count() or 1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--table", default="all",
                    choices=["all", "1", "2", "e2e", "pipeline_plans",
                             "loadgen", "fabric", "roofline", "trace",
                             "rollout", "lint"])
    ap.add_argument("--processes", default="1,2,4", metavar="N,N,...",
                    help="worker-process counts for --table fabric")
    ap.add_argument("--naive", action="store_true",
                    help="include the naive per-filter conv condition")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the result rows as a JSON list")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="for --table trace: also export the collected "
                         "spans as Chrome trace-event JSON (Perfetto)")
    args = ap.parse_args()

    from benchmarks import (e2e_pipeline, loadgen, pipeline_plans,
                            rollout_bench, roofline_table,
                            table1_feedforward, table2_service, trace_table)
    from benchmarks.common import build_world

    rows = []
    world = None
    if args.table in ("all", "1", "2", "e2e", "pipeline_plans", "loadgen",
                      "trace", "rollout"):
        world = build_world()
    if args.table in ("all", "1"):
        rows += table1_feedforward.run(batch=1, world=world, naive=args.naive)
        rows += table1_feedforward.run(batch=64, world=world)
        rows += table1_feedforward.paper_size_contrast()
    if args.table in ("all", "2"):
        rows += table2_service.run(world=world)
    if args.table in ("all", "e2e"):
        rows += e2e_pipeline.run(world=world)
    if args.table in ("all", "pipeline_plans"):
        rows += pipeline_plans.run(world=world)
    if args.table in ("all", "loadgen"):
        rows += loadgen.run(world=world)
    if args.table == "fabric":
        # Not in "all": each process count spawns/tears down a worker
        # fleet (several seconds of process startup per level), so the
        # sweep runs only when asked for.
        rows += loadgen.run_fabric(
            tuple(int(x) for x in args.processes.split(",")))
    if args.table in ("all", "roofline"):
        rows += roofline_table.run()
    if args.table in ("all", "lint"):
        # Cheap (no world needed): times the repro-lint hard gate over
        # the real tree plus the sanitizer's per-acquisition overhead.
        from benchmarks import lint_bench
        rows += lint_bench.run()
    if args.table == "rollout":
        # Not in "all": it drives a live 2-replica pool with closed-loop
        # client threads for a couple of seconds per condition.
        rows += rollout_bench.run(world=world)
    if args.table == "trace":
        # Not in "all": it stands up its own served pipeline and toggles
        # the process-wide tracer for the overhead measurement.
        rows += trace_table.run(world=world, trace_out=args.trace_out)

    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    if args.json:
        meta = snapshot_meta()
        for r in rows:
            r.update(meta)
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2, sort_keys=True)
        print(f"# wrote {len(rows)} rows to {args.json} "
              f"(sha={meta['git_sha']} utc={meta['utc']} "
              f"cores={meta['host_cores']:g})")


if __name__ == "__main__":
    main()
