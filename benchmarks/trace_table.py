"""Per-stage latency table from the tracing fabric, plus telemetry overhead.

Two measurements:

  * ``trace/<span>`` rows — fire a query batch through a *served* pipeline
    (``ThreadPoolServer`` over a ``PipelineEngine`` whose rerank dispatches
    into an in-process ``ReplicaPool``), so one request traverses the full
    instrumented path: client RPC -> server dispatch -> admission ->
    plan stages -> micro-batcher queue/compute -> scorer. The finished
    spans are aggregated by name (``telemetry.stage_breakdown``): the
    answer to "where did this query's time go", as a table.
  * ``trace/overhead`` row — the pipeline_plans batched measurement run
    with tracing disabled vs enabled; derived reports the relative cost of
    the instrumentation itself (acceptance target: < 5%).

  PYTHONPATH=src python -m benchmarks.trace_table
  PYTHONPATH=src python -m benchmarks.run --table trace --trace-out t.json
"""
from __future__ import annotations

import gc
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan
from repro.serving import telemetry

BATCH = 32


def _pipeline(scorer):
    return ops.Retrieve(h=10) >> ops.Rerank(scorer, k=5)


def run(world=None, backend: str = "jit", n_queries: int = 60,
        trace_out: Optional[str] = None, overhead_reps: int = 3
        ) -> List[Dict]:
    cfg, params, corpus, tok, index, _ = world or build_world()
    queries = corpus.questions[:n_queries]
    measured, warm = queries[:BATCH], queries[BATCH:]

    scorer = BK.make_scorer(backend, params, cfg, buckets=(64, 256, 1024))
    for b in (64, 256, 1024):           # precompile: no jit in timed loops
        scorer(np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, cfg.max_len), np.int32),
               np.zeros((b, 4), np.float32))
    pipeline = _pipeline(scorer)

    # ---- served path: every hop of the request is instrumented ----------
    from repro.serving.cluster import ReplicaPool
    from repro.serving.engine import PipelineEngine
    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(64, 256, 1024),
                             policy="least_outstanding")
    engine = PipelineEngine(
        pipeline, PlanContext.from_world(cfg, params, corpus, tok, index,
                                         remote=pool),
        target="remote")
    srv = SV.ThreadPoolServer(engine).start_background()
    rows: List[Dict] = []
    try:
        with SV.Client(srv.address) as client:
            client.rank_batch(list(warm))
            telemetry.reset_all()       # keep only the measured traffic
            for q in measured:
                client.rank_batch([q])  # one trace per query
        spans = telemetry.get_tracer().finished()
        if trace_out:
            n = telemetry.export_chrome_trace(trace_out, spans)
            print(f"# wrote {n} trace events to {trace_out}")
        for name, agg in sorted(telemetry.stage_breakdown(spans).items()):
            rows.append({
                "name": f"trace/{name}",
                "us_per_call": 1e3 * agg["mean_ms"],
                "derived": (f"count={int(agg['count'])}"
                            f" total_ms={agg['total_ms']:.1f}"),
            })
    finally:
        srv.stop()
        pool.stop()

    # ---- instrumentation overhead on the batched plan -------------------
    # Mirrors the pipeline_plans jit-batched measurement: same pipeline,
    # fresh context per condition (so neither warms the other's caches),
    # identical warm/measured query split, tracing toggled process-wide.
    tracer = telemetry.get_tracer()
    timings: Dict[str, float] = {}
    plans = {}
    try:
        for mode in ("off", "on"):
            ctx = PlanContext.from_world(cfg, params, corpus, tok, index)
            plans[mode] = plan(pipeline, "batched", ctx)
            plans[mode].run_many(warm)
            tracer.set_enabled(mode == "on")
            gc.collect()
            t0 = time.perf_counter()
            for _ in range(overhead_reps):
                plans[mode].run_many(measured)
            timings[mode] = time.perf_counter() - t0
    finally:
        tracer.set_enabled(True)
        for p in plans.values():
            p.close()
    overhead = timings["on"] / timings["off"] - 1.0
    rows.append({
        "name": f"trace/overhead-{backend}-batched",
        "us_per_call": (1e6 * timings["on"]
                        / (overhead_reps * len(measured))),
        "derived": (f"overhead={100 * overhead:+.1f}%"
                    f" off_us={1e6 * timings['off'] / (overhead_reps * len(measured)):.1f}"),
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
