"""Paper Table 2: end-to-end RPC service performance — throughput (QPS) and
p50/p99 latency, single-threaded client, TSimpleServer-style server, both on
this host (exactly the paper's setup). Overhead vs Table 1 is the
serialization+transport cost of the service boundary.
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import build_world, percentile_stats
from repro.core import backends as BK
from repro.core import service as SV

BACKENDS = ("jit", "aot", "numpy")


def run(n_requests: int = 300, world=None) -> List[Dict]:
    cfg, params, corpus, tok, index, pairs = world or build_world()
    reqs = []
    for qi, di, si, _ in (pairs * 4)[:n_requests]:
        reqs.append((corpus.questions[qi], corpus.documents[di][si]))
    rows = _engine_rows(cfg, params, corpus, tok, reqs)
    for backend in BACKENDS:
        scorer = BK.make_scorer(backend, params, cfg, buckets=(1, 8, 64))
        handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                              cfg.max_len)
        srv = SV.SimpleServer(handler).start_background()
        cl = SV.Client(srv.address)
        cl.get_score(*reqs[0])  # warm
        lats = []
        t0 = time.perf_counter()
        for q, a in reqs:
            t1 = time.perf_counter()
            cl.get_score(q, a)
            lats.append(time.perf_counter() - t1)
        dt = time.perf_counter() - t0
        cl.close()
        srv.stop()
        p50, p99 = percentile_stats(lats)
        rows.append({"name": f"table2/{backend}-rpc",
                     "us_per_call": 1e6 * dt / len(reqs),
                     "derived": (f"qps={len(reqs) / dt:.1f} "
                                 f"p50_ms={p50 * 1e3:.2f} p99_ms={p99 * 1e3:.2f}")})
    return rows


def _engine_rows(cfg, params, corpus, tok, reqs) -> List[Dict]:
    """Beyond-paper: micro-batched ServingEngine under 8 concurrent
    clients vs the paper's one-at-a-time TSimpleServer discipline."""
    import threading

    from repro.core import backends as BK
    from repro.serving.engine import ServingEngine

    scorer = BK.make_scorer("jit", params, cfg, buckets=(1, 8, 64))
    eng = ServingEngine(scorer, tok, corpus.idf, cfg.max_len,
                        max_batch=64, max_wait_s=0.001)
    eng.get_score(*reqs[0])  # warm
    per_client = max(len(reqs) // 8, 1)

    def client(cid):
        for q, a in reqs[cid * per_client:(cid + 1) * per_client]:
            eng.get_score(q, a)

    t0 = time.perf_counter()
    threads = [threading.Thread(target=client, args=(c,)) for c in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    n = per_client * 8
    s = eng.stats()
    eng.stop()
    return [{"name": "table2/engine-microbatch-8clients",
             "us_per_call": 1e6 * dt / n,
             "derived": (f"qps={n / dt:.1f} p50_ms={s['p50_ms']:.2f} "
                         f"p99_ms={s['p99_ms']:.2f} "
                         f"mean_batch={s['mean_batch']:.1f}")}]


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
