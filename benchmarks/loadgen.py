"""Open-loop Poisson load generator for the RPC serving cluster.

Closed-loop benchmarks (issue, wait, repeat — Table 2's client) hide
queueing collapse: the client slows down with the server, so offered load
sags exactly when the system saturates. This generator is open-loop: a
Poisson arrival schedule is fixed up front at an offered QPS and every
request is launched at its scheduled time whether or not earlier ones have
completed, so latency includes the queueing delay a real user would see
(coordinated-omission-free: lateness counts from the SCHEDULED arrival).

``sweep`` walks offered QPS levels and reports achieved throughput with
p50/p99 — the throughput-vs-tail-latency curve for SimpleServer vs
ThreadPoolServer x replicas that extends the paper's Table 2. Shed replies
(MSG_SHED from admission control) are counted separately from errors:
under overload a well-behaved cluster sheds fast instead of queueing
unboundedly.

Ranking-RPC mode (``run_level(mode="rank")``) drives wire-v3 whole-pipeline
requests (``Client.rank``) instead of pair scoring; ``run_hedged`` stands up
two pipeline-serving replicas — one artificially slowed — and contrasts the
p99 of unhedged round-robin dispatch against hedged dispatch
(``serving.hedge.HedgedTransport``: same code path with the hedge delay set
to infinity for the unhedged baseline).

Process-scaling mode (``run_fabric`` / ``--processes``) spawns N
pipeline-serving worker PROCESSES behind the health-probed hedging router
(``serving.fabric``) and drives the open-loop rank schedule through the
router — the multi-core scaling curve the in-process thread cluster
structurally cannot produce (featurization holds the GIL). Rows record
``host_cores``: on a single-core host every process count shares one core,
so the curve is flat by construction there.

  PYTHONPATH=src python -m benchmarks.loadgen            # standalone sweep
  PYTHONPATH=src python -m benchmarks.loadgen --processes 1,2,4   # fabric
  PYTHONPATH=src python -m benchmarks.run --table loadgen --json out.json
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import service as SV
from repro.core import wire


def poisson_arrivals(offered_qps: float, duration_s: float,
                     seed: int = 0) -> List[float]:
    """Exponential inter-arrival times at rate ``offered_qps``."""
    rng = random.Random(seed)
    t, out = 0.0, []
    while True:
        t += rng.expovariate(offered_qps)
        if t >= duration_s:
            return out
        out.append(t)


def run_level(address: Tuple[str, int], reqs: Sequence,
              offered_qps: float, duration_s: float, n_conns: int = 4,
              deadline_s: Optional[float] = None, seed: int = 0,
              mode: str = "score") -> Dict[str, float]:
    """Drive one offered-QPS level with ``n_conns`` persistent connections.

    Arrivals are struck round-robin across connections; a connection that
    falls behind its schedule fires immediately and the lateness shows up
    in the measured latency (open-loop semantics).

    ``mode="score"`` drives pair-scoring RPCs (``reqs`` holds (q, a)
    pairs); ``mode="rank"`` drives v3 whole-pipeline ranking RPCs
    (``reqs`` holds query strings, one ``Client.rank`` per arrival).

    ``address`` may instead be a callable ``factory(wid) -> client`` for
    transports that are not one socket per connection (the fabric sweep
    passes router-backed connections so requests route least-loaded across
    worker processes).
    """
    arrivals = poisson_arrivals(offered_qps, duration_s, seed)
    lock = threading.Lock()
    lats: List[float] = []
    counts = {"ok": 0, "shed": 0, "error": 0}
    clients: List[SV.Client] = []
    stop = threading.Event()
    t0_box = [0.0]
    last_done = [0.0]

    def worker(wid: int):
        try:
            cl = address(wid) if callable(address) else SV.Client(address)
        except OSError:
            with lock:
                counts["error"] += len(arrivals[wid::n_conns])
            return
        with lock:
            clients.append(cl)
        for i, at in list(enumerate(arrivals))[wid::n_conns]:
            if stop.is_set():
                break
            wait = at - (time.perf_counter() - t0_box[0])
            if wait > 0:
                time.sleep(wait)
            req = reqs[i % len(reqs)]
            try:
                # The deadline is a budget from the SCHEDULED arrival: a
                # request fired late (connection behind schedule) has
                # already burned part of it, so the server can shed it as
                # expired — the wire deadline is relative to send time.
                budget = deadline_s
                if budget is not None:
                    budget -= (time.perf_counter() - t0_box[0]) - at
                if mode == "rank":
                    cl.rank(req, deadline_s=budget)
                else:
                    cl.get_score(req[0], req[1], deadline_s=budget)
                done = time.perf_counter() - t0_box[0]
                with lock:
                    lats.append(done - at)
                    counts["ok"] += 1
                    last_done[0] = max(last_done[0], done)
            except wire.ShedError:
                with lock:
                    counts["shed"] += 1
            except (ConnectionError, OSError, RuntimeError, ValueError):
                if stop.is_set():
                    break
                with lock:
                    counts["error"] += 1

    threads = [threading.Thread(target=worker, args=(w,), daemon=True)
               for w in range(n_conns)]
    t0_box[0] = time.perf_counter()
    for t in threads:
        t.start()
    # Grace beyond the schedule for in-flight requests, then force-stop:
    # workers stuck behind a saturated server (e.g. SimpleServer never
    # accepting their connection) are unblocked by closing their sockets.
    deadline_join = duration_s + max(2.0, duration_s)
    for t in threads:
        t.join(timeout=max(deadline_join - (time.perf_counter() - t0_box[0]),
                           0.05))
    stop.set()
    with lock:
        snapshot = list(clients)
    for cl in snapshot:
        cl.reconnect = False
        try:
            cl.close()
        except OSError:
            pass
    for t in threads:
        t.join(timeout=1.0)
    with lock:
        # Sustained-throughput window: the schedule length, extended to the
        # last completion (stuck connections don't inflate it forever).
        elapsed = max(duration_s, last_done[0])
        xs = sorted(lats)
        done = dict(counts)
    from repro.serving.stats import LatencyTracker
    pct = LatencyTracker._interp_percentile
    n_sched = len(arrivals)
    return {
        "offered_qps": offered_qps,
        "achieved_qps": done["ok"] / max(elapsed, 1e-9),
        "p50_ms": pct(xs, 0.50) * 1e3,
        "p99_ms": pct(xs, 0.99) * 1e3,
        "n_scheduled": float(n_sched),
        "n_ok": float(done["ok"]),
        "n_shed": float(done["shed"]),
        "n_error": float(done["error"]),
        "shed_rate": done["shed"] / max(n_sched, 1),
        "duration_s": elapsed,
        "n_conns": float(n_conns),
    }


def sweep(address, reqs, qps_levels: Sequence[float], duration_s: float,
          n_conns: int = 4, deadline_s: Optional[float] = None,
          seed: int = 0) -> List[Dict[str, float]]:
    return [run_level(address, reqs, qps, duration_s, n_conns,
                      deadline_s, seed + i)
            for i, qps in enumerate(qps_levels)]


class _SlowRankHandler:
    """Wrap a pipeline handler with a fixed per-request delay — the
    'one artificially slow replica' of the hedging experiment (a straggler
    from GC, paging, a noisy neighbor...)."""

    def __init__(self, inner, delay_s: float):
        self._inner = inner
        self._delay_s = delay_s
        self.rows_per_query = getattr(inner, "rows_per_query", 1)

    def rank_batch(self, queries):
        time.sleep(self._delay_s)
        return self._inner.rank_batch(queries)


def run_hedged(world=None, backend: str = "jit", n_requests: int = 60,
               slow_delay_s: float = 0.05, hedge_s: float = 0.005
               ) -> List[Dict]:
    """Hedged vs unhedged ranking dispatch over two pipeline replicas, one
    slowed by ``slow_delay_s`` per request. Round-robin routing means the
    unhedged client eats the full delay on half its requests; the hedged
    client races the other replica after ``hedge_s`` and its p99 collapses
    to roughly hedge delay + fast service time."""
    from benchmarks.common import build_world
    from repro.core import ops
    from repro.core.plan import PlanContext
    from repro.serving.engine import PipelineEngine
    from repro.serving.hedge import HedgedTransport
    from repro.serving.stats import LatencyTracker

    cfg, params, corpus, tok, index, _ = world or build_world()
    pipeline = ops.Retrieve(h=10) >> ops.Rerank(backend, k=5)
    queries = corpus.questions[:16]

    def make_engine():
        return PipelineEngine(
            pipeline,
            PlanContext.from_world(cfg, params, corpus, tok, index,
                                   buckets=(64, 256, 1024)),
            target="batched")

    fast_eng, slow_eng = make_engine(), make_engine()
    srv_fast = SV.SimpleServer(fast_eng).start_background()
    srv_slow = SV.SimpleServer(
        _SlowRankHandler(slow_eng, slow_delay_s)).start_background()

    rows: List[Dict] = []
    pct = LatencyTracker._interp_percentile
    try:
        for tag, hedge in (("unhedged", float("inf")), ("hedged", hedge_s)):
            # Two clients (one socket per replica); hedge=inf IS the
            # unhedged baseline — identical code path, no second attempt.
            ht = HedgedTransport([SV.Client(srv_fast.address),
                                  SV.Client(srv_slow.address)],
                                 hedge_s=hedge)
            try:
                ht.rank(queries[0])     # warm compiled entries both ways
                ht.rank(queries[1])
                lats = []
                t0 = time.perf_counter()
                for i in range(n_requests):
                    t1 = time.perf_counter()
                    ht.rank(queries[i % len(queries)])
                    lats.append(time.perf_counter() - t1)
                dt = time.perf_counter() - t0
            finally:
                ht.close()
            xs = sorted(lats)
            s = ht.stats()
            rows.append({
                "name": f"loadgen/rank-{tag}",
                "us_per_call": 1e6 * dt / n_requests,
                "derived": (f"qps={n_requests / dt:.1f} "
                            f"p50_ms={pct(xs, 0.50) * 1e3:.2f} "
                            f"p99_ms={pct(xs, 0.99) * 1e3:.2f} "
                            f"hedged={int(s['hedged'])} "
                            f"hedge_wins={int(s['hedge_wins'])}"),
                "hedge": {"p50_ms": pct(xs, 0.50) * 1e3,
                          "p99_ms": pct(xs, 0.99) * 1e3,
                          "slow_delay_ms": slow_delay_s * 1e3,
                          **s},
            })
        # The v3 ranking service under open-loop Poisson load (run_level's
        # ranking-RPC mode): one Client.rank per scheduled arrival against
        # the fast replica.
        lvl = run_level(srv_fast.address, queries, offered_qps=50.0,
                        duration_s=1.0, n_conns=1, mode="rank")
        qps = max(lvl["achieved_qps"], 1e-9)
        rows.append({
            "name": "loadgen/rank-openloop-offered50",
            "us_per_call": 1e6 / qps,
            "derived": (f"qps={lvl['achieved_qps']:.1f} "
                        f"p50_ms={lvl['p50_ms']:.2f} "
                        f"p99_ms={lvl['p99_ms']:.2f} "
                        f"err={int(lvl['n_error'])}"),
            "loadgen": lvl,
        })
    finally:
        srv_fast.stop()
        srv_slow.stop()
    return rows


class _RouterConn:
    """One loadgen 'connection' over the fabric's shared router. The
    router serializes attempts per worker endpoint (one socket each), so
    M concurrent _RouterConns keep at most n_workers requests in flight —
    exactly the fleet's service parallelism. The router owns the sockets;
    close here is a no-op."""

    reconnect = False

    def __init__(self, router):
        self._router = router

    def rank(self, query, deadline_s=None):
        # The router's hedge path retries sheds/drains on the backup
        # worker; per-request deadlines stay client-side here (the
        # HedgedTransport protocol methods carry no deadline).
        return self._router.rank(query)

    def close(self):
        pass


def run_fabric(process_counts: Sequence[int] = (1, 2, 4),
               offered_qps: float = 60.0, duration_s: float = 3.0,
               backend: str = "numpy", train_steps: int = 1) -> List[Dict]:
    """Process-scaling sweep: for each N, spawn N pipeline-serving worker
    processes behind the health-probed hedging router and drive the same
    open-loop rank schedule through it. The client side needs only the
    query strings (the deterministic demo corpus), not a trained world —
    every worker process builds its own.

    Rows record ``host_cores``; interpret the curve against it (N worker
    processes on one core time-share that core, so the single-core curve
    is flat — the fabric removes the GIL ceiling, not the hardware's).
    """
    import os

    from repro.data import qa as QA
    from repro.serving.fabric import Fabric

    queries = QA.generate_corpus(n_docs=80, n_questions=60,
                                 seed=0).questions
    host_cores = float(os.cpu_count() or 1)
    rows: List[Dict] = []
    for n in process_counts:
        with Fabric(n_workers=n, backend=backend,
                    train_steps=train_steps) as fab:
            router = fab.router
            for q in queries[:max(2 * n, 4)]:
                router.rank(q)          # warm every worker's scoring path
            lvl = run_level(lambda wid: _RouterConn(router), queries,
                            offered_qps, duration_s,
                            n_conns=max(2 * n, 4), mode="rank")
            qps = max(lvl["achieved_qps"], 1e-9)
            rs = router.stats()
            rows.append({
                "name": f"loadgen/fabric-x{n}-offered{int(offered_qps)}",
                "us_per_call": 1e6 / qps,
                "derived": (f"qps={lvl['achieved_qps']:.1f} "
                            f"p50_ms={lvl['p50_ms']:.2f} "
                            f"p99_ms={lvl['p99_ms']:.2f} "
                            f"err={int(lvl['n_error'])} "
                            f"workers={n} "
                            f"host_cores={int(host_cores)}"),
                "fabric": {**lvl, "n_workers": float(n),
                           "host_cores": host_cores,
                           **{f"router_{k}": v for k, v in rs.items()}},
            })
    return rows


def _make_requests(corpus, pairs, n: int):
    reqs = []
    for qi, di, si, _ in (pairs * 50)[:n]:
        reqs.append((corpus.questions[qi], corpus.documents[di][si]))
    return reqs


def run(world=None, qps_levels: Sequence[float] = (100.0, 300.0),
        duration_s: float = 1.5, n_conns: int = 4, replicas: int = 2,
        backend: str = "jit") -> List[Dict]:
    """Benchmark entry (benchmarks.run): SimpleServer vs ThreadPoolServer x
    replicas on the same backend, same offered-QPS sweep, plus one overload
    level demonstrating deadline/queue shedding."""
    from benchmarks.common import build_world
    from repro.serving.admission import AdmissionController
    from repro.serving.cluster import ReplicaPool
    from repro.core import backends as BK

    cfg, params, corpus, tok, index, pairs = world or build_world()
    reqs = _make_requests(corpus, pairs, 512)
    rows: List[Dict] = []

    def to_row(tag: str, r: Dict[str, float]) -> Dict:
        qps = max(r["achieved_qps"], 1e-9)
        return {"name": f"loadgen/{tag}-offered{int(r['offered_qps'])}",
                "us_per_call": 1e6 / qps,
                "derived": (f"qps={r['achieved_qps']:.1f} "
                            f"p50_ms={r['p50_ms']:.2f} "
                            f"p99_ms={r['p99_ms']:.2f} "
                            f"shed={int(r['n_shed'])} "
                            f"err={int(r['n_error'])}"),
                "loadgen": r}

    # -- paper-faithful single-threaded server ------------------------------
    scorer = BK.make_scorer(backend, params, cfg, buckets=(1, 8, 64))
    handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                          cfg.max_len)
    srv = SV.SimpleServer(handler).start_background()
    with SV.Client(srv.address) as cl:
        cl.get_score(*reqs[0])  # warm the compiled entry
    for r in sweep(srv.address, reqs, qps_levels, duration_s, n_conns):
        rows.append(to_row("simple", r))
    srv.stop()

    # -- threadpool server over a replica pool ------------------------------
    pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                             n_replicas=replicas, buckets=(1, 8, 64),
                             policy="least_outstanding")
    # Warm every replica at every coalescing bucket so runtime jit
    # compilation doesn't masquerade as tail latency in the sweep.
    for bucket in (1, 8, 64):
        q_tok, a_tok, feats = pool._featurize_batch(reqs[:bucket])
        for rep in pool.replicas:
            rep.batcher.submit_many(q_tok, a_tok, feats).result()
    admission = AdmissionController(max_queue_rows=256)
    srv = SV.ThreadPoolServer(pool, num_workers=max(n_conns * 2, 8),
                              admission=admission).start_background()
    with SV.Client(srv.address) as cl:
        cl.get_score(*reqs[0])
    tag = f"threadpool-x{replicas}"
    for r in sweep(srv.address, reqs, qps_levels, duration_s, n_conns):
        rows.append(to_row(tag, r))
    srv.stop()

    # Overload: many connections offering far past capacity against a tight
    # queue bound and deadline — the cluster must shed (SHED replies)
    # rather than queue unboundedly.
    over_conns = max(n_conns * 4, 16)
    srv = SV.ThreadPoolServer(pool, num_workers=over_conns,
                              admission=AdmissionController(max_queue_rows=8)
                              ).start_background()
    over = run_level(srv.address, reqs, offered_qps=qps_levels[-1] * 10,
                     duration_s=min(duration_s, 1.0), n_conns=over_conns,
                     deadline_s=0.05)
    rows.append(to_row(f"{tag}-overload", over))
    srv.stop()
    pool.stop()

    # Tail tolerance: hedged vs unhedged ranking RPCs with one replica
    # artificially slowed (Dean & Barroso's experiment in miniature).
    rows += run_hedged(world=(cfg, params, corpus, tok, index, pairs),
                       backend=backend)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--processes", default=None, metavar="N,N,...",
                    help="fabric process-scaling sweep over these worker-"
                         "process counts (e.g. 1,2,4) instead of the "
                         "default server sweep")
    ap.add_argument("--qps", type=float, default=60.0,
                    help="offered QPS for the fabric sweep")
    ap.add_argument("--duration", type=float, default=3.0,
                    help="seconds per fabric sweep level")
    ap.add_argument("--backend", default="numpy",
                    help="worker scorer backend for the fabric sweep")
    ap.add_argument("--train-steps", type=int, default=1,
                    help="worker training steps for the fabric sweep")
    cli = ap.parse_args()
    if cli.processes:
        counts = tuple(int(x) for x in cli.processes.split(","))
        out = run_fabric(counts, offered_qps=cli.qps,
                         duration_s=cli.duration, backend=cli.backend,
                         train_steps=cli.train_steps)
    else:
        out = run()
    for row in out:
        print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
