"""End-to-end multi-stage QA pipeline throughput (the paper's deployment
context): BM25 retrieval -> (optional cutoff) -> CNN rerank, per backend.

Each condition declares ONE pipeline with the operator algebra
(``repro.core.ops``) and measures two lowerings of it (``repro.core.plan``):

  local    — sequential per-query cascade (per-query scorer dispatch, query
             re-encoded once per candidate) — the legacy
             ``MultiStageRanker.run`` schedule;
  batched  — ``BatchedMultiStageRanker``'s coalesced schedule over a
             32-query batch (one coalesced BM25 scoring call, one
             featurization pass, bucketed cross-query scorer batches).

Both paths warm on queries DISJOINT from the measured set, so the batched
row measures batching (shared corpus sentences do hit its featurization
cache — that reuse is inherent to cross-query execution — but none of the
measured queries or pairs are pre-cached). Each condition gets a fresh
plan context for the same reason: plans built from one context share its
featurization cache. The batched rows carry the measured speedup vs. their
local twin; ``verify_plans`` first checks identical rankings."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import build_world, percentile_stats
from repro.core import backends as BK
from repro.core import ops
from repro.core.plan import PlanContext, plan, verify_plans

BATCH = 32


def run(n_queries: int = 60, world=None) -> List[Dict]:
    if n_queries <= BATCH:
        raise ValueError(f"n_queries must exceed {BATCH} so the warm-up "
                         f"set stays disjoint from the measured batch")
    cfg, params, corpus, tok, index, _ = world or build_world()
    queries = corpus.questions[:n_queries]      # unique texts
    measured, warm = queries[:BATCH], queries[BATCH:]
    rows = []
    for backend in ("jit", "aot", "numpy"):
        for cutoff in (False, True):
            scorer = BK.make_scorer(backend, params, cfg,
                                    buckets=(64, 256, 1024))
            for b in (64, 256, 1024):   # compile every bucket up front so
                scorer(np.zeros((b, cfg.max_len), np.int32),  # neither path
                       np.zeros((b, cfg.max_len), np.int32),  # pays jit in
                       np.zeros((b, 4), np.float32))          # the timed loop
            pipeline = ops.Retrieve(h=10)
            if cutoff:
                pipeline = pipeline >> ops.DynamicCutoff(margin=2.0)
            pipeline = pipeline >> ops.Rerank(scorer, k=5)
            # verification and measurement get separate contexts: plans
            # from one context share its featurization cache, and the
            # measured batched plan's cache must not see measured pairs
            vctx = PlanContext.from_world(cfg, params, corpus, tok, index)
            verify_plans([plan(pipeline, "local", vctx),
                          plan(pipeline, "batched", vctx)], measured[:8])
            ctx = PlanContext.from_world(cfg, params, corpus, tok, index)
            local = plan(pipeline, "local", ctx)
            batched = plan(pipeline, "batched", ctx)

            local.run(warm[0])  # warm compiled entries
            lats = []
            t0 = time.perf_counter()
            for q in measured:
                t1 = time.perf_counter()
                local.run(q)
                lats.append(time.perf_counter() - t1)
            seq_dt = time.perf_counter() - t0
            p50, p99 = percentile_stats(lats)
            tag = f"e2e/{backend}" + ("+cutoff" if cutoff else "")
            rows.append({"name": tag,
                         "us_per_call": 1e6 * seq_dt / len(measured),
                         "derived": (f"qps={len(measured) / seq_dt:.1f} "
                                     f"p50_ms={p50 * 1e3:.2f} "
                                     f"p99_ms={p99 * 1e3:.2f}")})

            batched.run_many(warm)  # disjoint warm-up batch
            t0 = time.perf_counter()
            batched.run_many(measured)
            bat_dt = time.perf_counter() - t0
            rows.append({"name": tag + f"+batched{BATCH}",
                         "us_per_call": 1e6 * bat_dt / len(measured),
                         "derived": (f"qps={len(measured) / bat_dt:.1f} "
                                     f"speedup={seq_dt / bat_dt:.2f}x")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
