"""End-to-end multi-stage QA pipeline throughput (the paper's deployment
context): BM25 retrieval -> (optional cutoff) -> CNN rerank, per backend."""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import build_world, percentile_stats
from repro.core import backends as BK
from repro.core import pipeline as PL


def run(n_queries: int = 40, world=None) -> List[Dict]:
    cfg, params, corpus, tok, index, _ = world or build_world()
    queries = (corpus.questions * 3)[:n_queries]
    rows = []
    for backend in ("jit", "aot", "numpy"):
        for cutoff in (False, True):
            scorer = BK.make_scorer(backend, params, cfg,
                                    buckets=(64, 256, 1024))
            stages = [PL.RetrievalStage(index, corpus.documents, tok, h=10)]
            if cutoff:
                stages.append(PL.CutoffStage(margin=2.0))
            stages.append(PL.RerankStage(scorer, tok, corpus.idf,
                                         cfg.max_len, k=5))
            ranker = PL.MultiStageRanker(stages)
            ranker.run(queries[0])  # warm
            lats = []
            t0 = time.perf_counter()
            for q in queries:
                t1 = time.perf_counter()
                ranker.run(q)
                lats.append(time.perf_counter() - t1)
            dt = time.perf_counter() - t0
            p50, p99 = percentile_stats(lats)
            tag = f"e2e/{backend}" + ("+cutoff" if cutoff else "")
            rows.append({"name": tag,
                         "us_per_call": 1e6 * dt / len(queries),
                         "derived": (f"qps={len(queries) / dt:.1f} "
                                     f"p50_ms={p50 * 1e3:.2f} "
                                     f"p99_ms={p99 * 1e3:.2f}")})
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
