"""Static-gate and sanitizer cost rows.

Two questions the perf trajectory should answer per PR: what does the
repro-lint hard gate add to tier-1 wall time (serial vs one thread per
checker — the ``--jobs 0`` mode tier-1 actually runs), and what does the
runtime lock sanitizer cost per acquisition when a soak runs under
``REPRO_SANITIZE=1``.  The lint rows time the real repository tree under
the checked-in baseline, so they grow with the codebase; the lock rows
are a microbenchmark of the proxy overhead itself (uncontended
acquire/release, the common case on the serving hot path).
"""
from __future__ import annotations

import os
import threading
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(ROOT, "scripts", "lint_baseline.txt")


def _time_lint(jobs: int):
    from repro.analysis import runner
    t0 = time.perf_counter()
    res = runner.run(ROOT, baseline_path=BASELINE, jobs=jobs)
    return time.perf_counter() - t0, res


def _time_lock_loop(lk, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        with lk:
            pass
    return (time.perf_counter() - t0) / n


def run():
    rows = []
    serial_s, res = _time_lint(jobs=1)
    par_s, _ = _time_lint(jobs=0)
    total = len(res.findings) + len(res.suppressed)
    rows.append({
        "name": "lint_gate_serial",
        "us_per_call": serial_s * 1e6,
        "derived": f"full tree / {total} finding(s) incl suppressed",
    })
    rows.append({
        "name": "lint_gate_jobs0",
        "us_per_call": par_s * 1e6,
        "derived": f"speedup x{serial_s / max(par_s, 1e-9):.2f}",
    })

    from repro.analysis.sanitizer import Witness, wrap
    n = 50_000
    raw_us = _time_lock_loop(threading.Lock(), n) * 1e6
    san_us = _time_lock_loop(
        wrap(threading.Lock(), "Bench._lock", Witness()), n) * 1e6
    rows.append({
        "name": "lock_acquire_raw",
        "us_per_call": raw_us,
        "derived": f"{n} uncontended acquire/release",
    })
    rows.append({
        "name": "lock_acquire_sanitized",
        "us_per_call": san_us,
        "derived": f"overhead x{san_us / max(raw_us, 1e-9):.1f}",
    })
    return rows
