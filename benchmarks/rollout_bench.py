"""Rollout benchmark: hot-swap latency and its tail-latency cost.

Three questions a rollout operator asks, one row each:

  rollout_swap_idle        — how long does a full 2-replica pool hot-swap
                             take with no traffic (registry load + scorer
                             rebuild + replica-by-replica drain)?
  rollout_steady_p99       — baseline request p99 under closed-loop load,
                             no swaps.
  rollout_swap_churn_p99   — the same load while the pool hot-swaps every
                             ~150ms, alternating versions. The gap to
                             steady p99 is the price of a swap; the failed
                             count must be 0 (the zero-loss protocol).

  PYTHONPATH=src python -m benchmarks.rollout_bench
  PYTHONPATH=src python -m benchmarks.run --table rollout --json out.json
"""
from __future__ import annotations

import tempfile
import threading
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import build_world
from repro.core.registry import ModelRegistry
from repro.serving.cluster import ReplicaPool

N_CLIENTS = 3
PAIRS_PER_REQ = 8


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(q * len(xs)), len(xs) - 1)]


def _drive(pool, pairs, duration_s: float):
    """Closed-loop load from N_CLIENTS threads; returns (latencies_s,
    failures)."""
    latencies: List[float] = []
    failures: List[str] = []
    lock = threading.Lock()
    stop = threading.Event()

    def client():
        while not stop.is_set():
            t0 = time.perf_counter()
            try:
                pool.get_scores(pairs)
            except Exception as e:  # noqa: BLE001 — counted, benchmark
                with lock:
                    failures.append(repr(e))
                continue
            with lock:
                latencies.append(time.perf_counter() - t0)

    threads = [threading.Thread(target=client) for _ in range(N_CLIENTS)]
    for t in threads:
        t.start()
    time.sleep(duration_s)
    stop.set()
    for t in threads:
        t.join()
    return latencies, failures


def run(world=None, backend: str = "numpy",
        duration_s: float = 1.2) -> List[Dict]:
    if world is None:
        world = build_world()
    cfg, params, corpus, tok, index, _ = world
    pairs = [(corpus.questions[i % len(corpus.questions)],
              corpus.documents[i % len(corpus.documents)][0])
             for i in range(PAIRS_PER_REQ)]

    with tempfile.TemporaryDirectory() as reg_dir:
        registry = ModelRegistry(reg_dir)
        va = registry.publish(params, model=cfg.name).version_id
        vb = registry.publish(jax.tree.map(lambda x: x * 1.5, params),
                              model=cfg.name).version_id

        pool = ReplicaPool.build(backend, params, cfg, tok, corpus.idf,
                                 n_replicas=2, buckets=(1, 8, 64))
        try:
            pool.get_scores(pairs)                     # warm the scorers
            rows: List[Dict] = []

            # -- idle swap latency (alternate so every swap does real work)
            swap_times = []
            for target in (vb, va, vb, va):
                t0 = time.perf_counter()
                pool.swap_version(target, registry)
                swap_times.append(time.perf_counter() - t0)
            rows.append({
                "name": f"rollout_swap_idle_{backend}",
                "us_per_call": 1e6 * float(np.mean(swap_times)),
                "derived": f"swaps={len(swap_times)} replicas=2",
            })

            # -- steady-state baseline
            lat, failed = _drive(pool, pairs, duration_s)
            rows.append({
                "name": f"rollout_steady_p99_{backend}",
                "us_per_call": 1e6 * _percentile(lat, 0.99),
                "derived": (f"qps={len(lat) / duration_s:.1f} "
                            f"failed={len(failed)}"),
            })

            # -- the same load under swap churn
            churn_stop = threading.Event()
            swaps = [0]

            def churn():
                flip = [va, vb]
                while not churn_stop.is_set():
                    time.sleep(0.15)
                    pool.swap_version(flip[swaps[0] % 2], registry)
                    swaps[0] += 1

            churner = threading.Thread(target=churn)
            churner.start()
            lat_c, failed_c = _drive(pool, pairs, duration_s)
            churn_stop.set()
            churner.join()
            rows.append({
                "name": f"rollout_swap_churn_p99_{backend}",
                "us_per_call": 1e6 * _percentile(lat_c, 0.99),
                "derived": (f"qps={len(lat_c) / duration_s:.1f} "
                            f"swaps={swaps[0]} failed={len(failed_c)}"),
            })
            return rows
        finally:
            pool.stop()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
