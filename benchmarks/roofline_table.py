"""Render the roofline table from dry-run artifacts (artifacts/dryrun/)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(art_dir: str = ART) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render_markdown(recs: List[Dict], mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| step_ms | useful% | roofline% | peak GiB |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped: sub-quadratic-rule | — | — | — | — |")
            continue
        if not r.get("ok") or r.get("mesh") != mesh:
            continue
        ro = r["roofline"]
        peak = r["memory"]["peak_estimate_bytes"] / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.3g} | "
            f"{ro['memory_s']:.3g} | {ro['collective_s']:.3g} | "
            f"{ro['bottleneck']} | {ro['step_s'] * 1e3:.2f} | "
            f"{ro['useful_ratio'] * 100:.0f} | "
            f"{ro['roofline_frac'] * 100:.1f} | {peak:.2f} |")
    return "\n".join(lines)


def run(art_dir: str = ART) -> List[Dict]:
    recs = load_records(art_dir)
    ok = [r for r in recs if r.get("ok") and not r.get("skipped")]
    rows = []
    for r in ok:
        ro = r["roofline"]
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            "us_per_call": ro["step_s"] * 1e6,
            "derived": (f"bottleneck={ro['bottleneck']} "
                        f"frac={ro['roofline_frac'] * 100:.1f}%"),
        })
    return rows


if __name__ == "__main__":
    recs = load_records()
    print(render_markdown(recs))
