"""Shared benchmark setup (re-exported from repro.launch.world)."""
from repro.launch.world import (build_world, eval_batches,  # noqa: F401
                                percentile_stats, timed)
