"""Paper Table 1: feedforward throughput (QPS) per integration backend,
WITHOUT the service wrapper. The paper's method: iterate the dev/test QA
pairs, score each, divide count by elapsed time; single calling thread.

Backends = the paper's three strategies mapped to JAX/TPU (DESIGN.md §2)
plus the Pallas-fused path. ``--naive`` adds the loop-over-filters condition
(the paper's two-orders-of-magnitude ND4J observation).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import build_world, eval_batches
from repro.core import backends as BK
from repro.core import export as E
from repro.core import numpy_eval as NE

BACKENDS = ("eager", "jit", "aot", "numpy", "pallas", "artifact")


def run(batch: int = 1, n_pairs: int = 300, naive: bool = False,
        world=None) -> List[Dict]:
    cfg, params, corpus, tok, index, pairs = world or build_world()
    pairs = (pairs * ((n_pairs // len(pairs)) + 1))[:n_pairs]
    batches = eval_batches(corpus, tok, cfg, pairs, batch)
    rows = []
    for backend in BACKENDS:
        scorer = BK.make_scorer(backend, params, cfg,
                                buckets=(batch, 64, 256))
        scorer(batches[0]["q_tok"], batches[0]["a_tok"], batches[0]["feats"])
        t0 = time.perf_counter()
        n = 0
        for b in batches:
            scorer(b["q_tok"], b["a_tok"], b["feats"])
            n += batch
        dt = time.perf_counter() - t0
        rows.append({"name": f"table1/{backend}/b{batch}",
                     "us_per_call": 1e6 * dt / max(n, 1),
                     "derived": f"qps={n / dt:.1f}"})
    if naive:
        blob = E.dumps(params, meta={"filter_width": cfg.filter_width})
        ev = NE.NumpySMCNN.from_bytes(blob)
        b = batches[0]
        t0 = time.perf_counter()
        ev.get_score(b["q_tok"][:4], b["a_tok"][:4], b["feats"][:4], naive=True)
        dt = time.perf_counter() - t0
        rows.append({"name": f"table1/numpy-naive/b{batch}",
                     "us_per_call": 1e6 * dt / 4,
                     "derived": f"qps={4 / dt:.1f}"})
    return rows


def paper_size_contrast(n_pairs: int = 8) -> List[Dict]:
    """The §4.1 claim at the paper's REAL model dimensions (100 filters,
    width 5, d=50, seq 64): naive loop-over-filters vs im2col-GEMM in the
    same NumPy runtime. The paper reports two orders of magnitude."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import sm_cnn
    cfg = get_config("sm-cnn")          # FULL config
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    blob = E.dumps(params, meta={"filter_width": cfg.filter_width})
    ev = NE.NumpySMCNN.from_bytes(blob)
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (n_pairs, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (n_pairs, cfg.max_len)).astype(np.int32)
    f = rng.random((n_pairs, 4), np.float32)
    rows = []
    for tag, naive in (("gemm", False), ("naive", True)):
        ev.get_score(q[:1], a[:1], f[:1], naive=naive)  # warm
        t0 = time.perf_counter()
        ev.get_score(q, a, f, naive=naive)
        dt = time.perf_counter() - t0
        rows.append({"name": f"table1/paper-size-{tag}",
                     "us_per_call": 1e6 * dt / n_pairs,
                     "derived": f"qps={n_pairs / dt:.1f}"})
    ratio = rows[1]["us_per_call"] / rows[0]["us_per_call"]
    rows.append({"name": "table1/paper-size-naive-vs-gemm",
                 "us_per_call": 0.0, "derived": f"slowdown={ratio:.0f}x"})
    return rows


if __name__ == "__main__":
    for r in run(naive=True) + paper_size_contrast():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
