"""Training driver with checkpoint/restart and IR-style evaluation (MRR /
P@1 over held-out questions), demonstrating the fault-tolerant loop.

  PYTHONPATH=src python examples/train_reranker.py --steps 150
"""
import argparse
import functools
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw, warmup_cosine_schedule
from repro.training.train_loop import Trainer


def evaluate(params, cfg, corpus, tok, n_q: int = 20):
    """MRR and P@1 of the reranker over candidate sets per question."""
    scorer = BK.make_scorer("jit", params, cfg, buckets=(64, 256))
    by_q = {}
    for qi, di, si, label in corpus.pairs:
        by_q.setdefault(qi, []).append((di, si, label))
    mrr, p1, n = 0.0, 0, 0
    for qi, cands in list(by_q.items())[:n_q]:
        if not any(l for _, _, l in cands):
            continue
        batch = QA.make_batch(corpus, tok, cfg.max_len,
                              [(qi, di, si, l) for di, si, l in cands])
        s = scorer(batch["q_tok"], batch["a_tok"], batch["feats"])
        order = np.argsort(-s)
        labels = batch["label"][order]
        rank = int(np.argmax(labels)) + 1
        mrr += 1.0 / rank
        p1 += int(labels[0] == 1)
        n += 1
    return mrr / max(n, 1), p1 / max(n, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt = args.ckpt_dir or os.path.join(tempfile.gettempdir(), "repro_ckpt")

    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=100, n_questions=80, seed=0)
    tok = HashingTokenizer(cfg.vocab_size)
    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)

    trainer = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg),
                      adamw(warmup_cosine_schedule(3e-3, 20, args.steps)),
                      params, ckpt_dir=ckpt, ckpt_every=50)
    if trainer.restore():
        print(f"resumed from step {trainer.step} (crash-restart path)")

    def stream():
        epoch = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=epoch)
            epoch += 1

    mrr0, p10 = evaluate(trainer.params, cfg, corpus, tok)
    print(f"before: MRR={mrr0:.3f} P@1={p10:.3f}")
    trainer.run(stream(), max_steps=args.steps, log_every=25)
    mrr1, p11 = evaluate(trainer.params, cfg, corpus, tok)
    print(f"after:  MRR={mrr1:.3f} P@1={p11:.3f}")
    print(f"checkpoints in {ckpt}: steps {trainer.manager.list_steps()}")
    stragglers = trainer.monitor.flagged
    print(f"straggler steps flagged: {len(stragglers)}")


if __name__ == "__main__":
    main()
