"""The paper's deployment axis, end to end:

  1. train in the framework (PyTorch in the paper, JAX here),
  2. export weights to the language-agnostic container (their Avro),
  3. re-evaluate in a foreign runtime (their Deeplearning4J -> our NumPy),
  4. 'compile' the network into a standalone artifact (their C++ codegen ->
     our jax.export StableHLO bundle) and run it without the model code.

  PYTHONPATH=src python examples/export_and_compile.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.world import build_world
from repro.core import compiled_artifact as CA
from repro.core import export as E
from repro.core import numpy_eval as NE
from repro.models import sm_cnn


def main():
    cfg, params, corpus, tok, index, pairs = build_world(train_steps=60)
    tmp = tempfile.mkdtemp(prefix="repro_export_")

    batch = 8
    rng = np.random.default_rng(0)
    q = rng.integers(0, cfg.vocab_size, (batch, cfg.max_len)).astype(np.int32)
    a = rng.integers(0, cfg.vocab_size, (batch, cfg.max_len)).astype(np.int32)
    f = rng.random((batch, 4), np.float32)
    ref = np.asarray(sm_cnn.score(params, q, a, f, cfg))

    # -- 2: weight export (Avro analogue) --
    wpath = os.path.join(tmp, "sm_cnn.rpro")
    E.save(wpath, params, model=cfg.name,
           meta={"filter_width": cfg.filter_width})
    print(f"weights exported: {wpath} ({os.path.getsize(wpath)} bytes)")

    # -- 3: foreign-runtime feedforward (DL4J analogue) --
    ev = NE.NumpySMCNN.from_file(wpath)
    out_np = ev.get_score(q, a, f)
    print(f"numpy runtime  max|diff| = {np.abs(out_np - ref).max():.2e}")

    # -- 4: compiled standalone artifact (C++ codegen analogue) --
    frozen = jax.tree.map(jnp.asarray, params)
    blob = CA.build_artifact(
        lambda qq, aa, ff: sm_cnn.score(frozen, qq, aa, ff, cfg),
        {f"b{batch}": (jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32),
                       jax.ShapeDtypeStruct((batch, cfg.max_len), jnp.int32),
                       jax.ShapeDtypeStruct((batch, 4), jnp.float32))},
        meta={"model": cfg.name})
    apath = os.path.join(tmp, "sm_cnn.hlo")
    with open(apath, "wb") as fh:
        fh.write(blob)
    print(f"compiled artifact: {apath} ({len(blob)} bytes)")
    art = CA.CompiledArtifact.from_file(apath)
    out_art = np.asarray(art.call(f"b{batch}", q, a, f))
    print(f"artifact runtime max|diff| = {np.abs(out_art - ref).max():.2e}")
    print("parity across deployment paths confirmed")


if __name__ == "__main__":
    main()
