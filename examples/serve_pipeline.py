"""End-to-end serving driver (the paper's kind: serve a model with batched
requests): build a corpus + BM25 index, train the reranker, stand up the RPC
service, then drive it with a single-threaded client and report
QPS / p50 / p99 — the paper's Table 2 protocol — plus answers for a few
questions through the full multi-stage pipeline.

  PYTHONPATH=src python examples/serve_pipeline.py [--requests 200]

Server modes (also available via ``python -m repro.launch.serve``):

  --server simple      the paper's TSimpleServer: one thread, one connection
      at a time — a second client literally queues behind the first.
  --server threadpool  the TThreadPoolServer analogue: a worker pool
      multiplexes many connections onto a ``ReplicaPool`` of ``--replicas``
      independent scorer replicas (each with its own micro-batcher), routed
      by ``--policy`` (round_robin | least_outstanding | p2c) behind
      deadline-aware admission control (``--max-queue`` bounds outstanding
      rows; over-budget or expired requests get SHED replies instead of
      queueing — see repro.serving.admission).

Clients may attach a per-request deadline (``Client.get_score(q, a,
deadline_s=...)``, wire protocol v2); v1 clients without deadlines keep
working, and clients can opt into a bounded shed-retry budget
(``Client(addr, retry_sheds=N)``). For throughput-vs-tail-latency curves
under open-loop Poisson load, use ``python -m benchmarks.run --table
loadgen --json out.json``.

The pipeline section declares ONE ranking pipeline with the operator
algebra (repro.core.ops) and lowers it to three execution plans
(repro.core.plan):

  local    — sequential per-query cascade: every query pays its own BM25
             dispatch and scorer call;
  batched  — cross-query coalesced execution: one BM25 scoring call for the
             whole batch, one LRU-cached featurization pass, bucketed
             scorer batches — identical rankings, reported with speedup;
  remote   — the SAME pipeline with its rerank stage dispatching pairs
             through the RPC server stood up above;
  remote_pipeline
           — the SAME pipeline served WHOLE behind a second server (wire v3
             MSG_RANK_BATCH, handler = serving.engine.PipelineEngine): the
             client ships query strings, one RPC per batch, and gets ranked
             (doc_id, sent_id, score) lists back — no candidate pair ever
             crosses the wire. (``python -m repro.launch.serve
             --serve-pipeline`` stands up the same thing as a CLI service;
             lists of endpoints hedge through serving.hedge.)

``--fabric N`` runs the multi-process deployment demo instead: spawn N
pipeline-serving worker PROCESSES behind the health-probed hedging router
(repro.serving.fabric), sweep ranking traffic through the router, drain one
worker gracefully (finish in-flight, shed new work, route around it),
restart it (it rejoins and takes traffic again), and tear the fleet down —
the spawn -> sweep -> drain -> teardown cycle of a compose-style
deployment, against live local processes.
"""
import argparse
import gc
import time

import numpy as np

from repro.launch.world import build_world, percentile_stats
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan, verify_plans


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--server", default="simple",
                    choices=["simple", "threadpool"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="least_outstanding")
    ap.add_argument("--max-queue", type=int, default=512)
    ap.add_argument("--fabric", type=int, default=0, metavar="N",
                    help="run the multi-process fabric demo with N worker "
                         "processes (spawn -> sweep -> drain -> teardown) "
                         "instead of the in-process tour")
    args = ap.parse_args()

    if args.fabric > 0:
        fabric_demo(args.fabric)
        return

    print("== building world (corpus, index, trained reranker) ==")
    cfg, params, corpus, tok, index, pairs = build_world(train_steps=80)
    ctx = PlanContext.from_world(cfg, params, corpus, tok, index,
                                 buckets=(1, 8, 64, 256))

    print(f"== serving through RPC ({args.backend} backend, "
          f"{args.server} server) ==")
    pool = None
    if args.server == "simple":
        handler = SV.QuestionAnsweringHandler(ctx.scorer_for(args.backend),
                                              tok, corpus.idf, cfg.max_len)
        srv = SV.SimpleServer(handler).start_background()
    else:
        from repro.serving.admission import AdmissionController
        from repro.serving.cluster import ReplicaPool
        pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                                 n_replicas=args.replicas,
                                 buckets=(1, 8, 64, 256), policy=args.policy)
        admission = (AdmissionController(args.max_queue)
                     if args.max_queue > 0 else None)
        srv = SV.ThreadPoolServer(pool,
                                  admission=admission).start_background()
    client = SV.Client(srv.address)

    reqs = []
    for qi, di, si, _ in (pairs * 4)[: args.requests]:
        reqs.append((corpus.questions[qi], corpus.documents[di][si]))
    client.get_score(*reqs[0])  # warm the compiled entry

    lats = []
    t0 = time.perf_counter()
    for q, a in reqs:
        t1 = time.perf_counter()
        client.get_score(q, a)
        lats.append(time.perf_counter() - t1)
    dt = time.perf_counter() - t0
    p50, p99 = percentile_stats(lats)
    print(f"  {len(reqs)} requests  QPS={len(reqs)/dt:8.1f}  "
          f"p50={p50*1e3:.2f}ms  p99={p99*1e3:.2f}ms")

    # batched requests through the same service
    t0 = time.perf_counter()
    client.get_score_batch(reqs[:64])
    bdt = time.perf_counter() - t0
    print(f"  batched(64)          QPS={64/bdt:8.1f}")
    client.close()

    print("\n== one pipeline, four execution plans ==")
    pipeline = (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
                >> ops.Rerank(args.backend) % 3)
    print(f"  pipeline: {pipeline!r}")
    # whole-pipeline ranking service (wire v3): a second server whose
    # handler lowers and runs the SAME description server-side
    from repro.serving.engine import PipelineEngine
    rank_engine = PipelineEngine(
        pipeline, PlanContext.from_world(cfg, params, corpus, tok, index,
                                         buckets=(1, 8, 64, 256)),
        target="batched")
    rank_srv = SV.ThreadPoolServer(rank_engine).start_background()
    plans = {t: plan(pipeline, t, ctx) for t in ("local", "batched")}
    # remote: the same pipeline, rerank dispatched through the live server
    plans["remote"] = plan(pipeline, "remote", ctx=ctx, remote=srv.address)
    plans["remote_pipeline"] = plan(pipeline, "remote_pipeline", ctx=ctx,
                                    remote=rank_srv.address)
    for p in plans.values():
        print(f"  {p.describe()}")

    print("\n== multi-stage pipeline answers (remote plan) ==")
    for q in corpus.questions[:3]:
        final, trace = plans["remote"].run(q)
        stages = " -> ".join(f"{t.name}({len(t.candidates)}, "
                             f"{t.latency_s*1e3:.1f}ms)" for t in trace)
        print(f"  Q: {q}")
        print(f"     {stages}")
        if final:
            print(f"     A: {final[0].text}  (score {final[0].score:.3f})")

    print("\n== one ranking RPC, whole cascade server-side ==")
    q = corpus.questions[3]
    final, trace = plans["remote_pipeline"].run(q)
    print(f"  Q: {q}")
    print(f"     {trace[0].name}: {len(final)} ranked answers in "
          f"{trace[0].latency_s*1e3:.1f}ms (one MSG_RANK_BATCH round trip)")
    if final:
        print(f"     A: {final[0].text}  (score {final[0].score:.3f})")

    # Release the answer sections' connections first: the SimpleServer
    # serves one connection at a time, so a second live client would
    # queue behind it forever.
    plans["remote"].close()
    plans["remote_pipeline"].close()

    print("\n== plan throughput (32-query batch, identical rankings) ==")
    queries = corpus.questions[:32]
    warm = corpus.questions[32:]    # disjoint warm-up set
    # Fresh context per plan: with a shared featurization cache the first
    # timed plan would warm the measured queries for the later ones.
    # Verification runs AFTER the timed loop for the same reason.
    tplans = {t: plan(pipeline, t,
                      PlanContext.from_world(cfg, params, corpus, tok, index,
                                             buckets=(1, 8, 64, 256),
                                             remote=srv.address))
              for t in ("local", "batched", "remote")}
    tplans["remote_pipeline"] = plan(
        pipeline, "remote_pipeline",
        PlanContext.from_world(cfg, params, corpus, tok, index,
                               buckets=(1, 8, 64, 256),
                               remote=rank_srv.address))
    timings = {}
    for name, p in tplans.items():
        p.run_many(warm)            # measured queries stay cold
        gc.collect()                # don't let one plan eat the whole
        t0 = time.perf_counter()    # session's gen-2 GC pause mid-timing
        results = p.run_many(queries)
        timings[name] = time.perf_counter() - t0
        assert len(results) == len(queries)
    verify_plans(list(tplans.values()), queries[:8])
    cache = tplans["batched"].cache_stats()
    for name, dt in timings.items():
        extra = ""
        if name != "local":
            extra = f"  (speedup {timings['local'] / dt:.2f}x vs local)"
        print(f"  {name:8s} {len(queries)/dt:8.1f} q/s{extra}")
    print(f"  feat-cache hit rate {cache['feat_cache_hit_rate']:.0%}")

    for p in tplans.values():
        p.close()
    srv.stop()
    rank_srv.stop()
    if pool is not None:
        print("  cluster stats: " + " ".join(
            f"{k}={v:.1f}" for k, v in sorted(pool.stats().items())
            if k.endswith("_requests") or k == "outstanding_rows"))
        pool.stop()


def fabric_demo(n_workers: int):
    """Spawn -> sweep -> drain -> teardown against live worker processes
    (mirrors a compose deployment's up / load / drain-one / down cycle)."""
    from repro.data import qa as QA
    from repro.serving.fabric import Fabric

    queries = QA.generate_corpus(n_docs=80, n_questions=60,
                                 seed=0).questions

    print(f"== spawn: {n_workers} pipeline-serving worker processes ==")
    t0 = time.perf_counter()
    with Fabric(n_workers=n_workers, backend="numpy",
                train_steps=1) as fab:
        for w in fab.workers:
            print(f"  worker {w.slot} pid={w.proc.pid} addr={w.address}")
        print(f"  fleet ready in {time.perf_counter() - t0:.1f}s "
              f"(each process: own interpreter, jit cache, admission)")

        print("\n== sweep: ranking traffic through the health router ==")
        lats = []
        t0 = time.perf_counter()
        for i, q in enumerate(queries[:40]):
            t1 = time.perf_counter()
            fab.router.rank(q)
            lats.append(time.perf_counter() - t1)
        dt = time.perf_counter() - t0
        p50, p99 = percentile_stats(lats)
        print(f"  40 rank RPCs  QPS={40 / dt:6.1f}  p50={p50 * 1e3:.1f}ms "
              f"p99={p99 * 1e3:.1f}ms")
        for slot, snap in sorted(fab.router.snapshot().items()):
            print(f"  worker {slot} health: " + " ".join(
                f"{k}={v:g}" for k, v in sorted(snap.items())))

        print("\n== drain worker 0 (graceful: finish in-flight, shed new,"
              " route around) ==")
        snap = fab.drain_worker(0)
        print(f"  drained: inflight={snap['inflight']:g} "
              f"queue_depth={snap['queue_depth']:g}")
        for q in queries[40:44]:
            fab.router.rank(q)          # traffic keeps flowing on the rest
        print(f"  traffic continues on "
              f"{int(fab.router.stats()['routable_workers'])} "
              f"routable worker(s)")

        print("\n== restart worker 0 (drain -> terminate -> respawn ->"
              " rejoin) ==")
        addr = fab.restart_worker(0)
        print(f"  rejoined at {addr}; routable="
              f"{int(fab.router.stats()['routable_workers'])}")
        fab.router.rank(queries[44])
        s = fab.stats()
        print(f"  fabric stats: alive={int(s['alive_workers'])} "
              f"respawns={int(s['respawns'])} "
              f"hedged={int(s['router_hedged'])}")
    print("\n== teardown complete ==")


if __name__ == "__main__":
    main()
