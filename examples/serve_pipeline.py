"""End-to-end serving driver (the paper's kind: serve a model with batched
requests): build a corpus + BM25 index, train the reranker, stand up the RPC
service, then drive it with a single-threaded client and report
QPS / p50 / p99 — the paper's Table 2 protocol — plus answers for a few
questions through the full multi-stage pipeline.

  PYTHONPATH=src python examples/serve_pipeline.py [--requests 200]

Server modes (also available via ``python -m repro.launch.serve``):

  --server simple      the paper's TSimpleServer: one thread, one connection
      at a time — a second client literally queues behind the first.
  --server threadpool  the TThreadPoolServer analogue: a worker pool
      multiplexes many connections onto a ``ReplicaPool`` of ``--replicas``
      independent scorer replicas (each with its own micro-batcher), routed
      by ``--policy`` (round_robin | least_outstanding | p2c) behind
      deadline-aware admission control (``--max-queue`` bounds outstanding
      rows; over-budget or expired requests get SHED replies instead of
      queueing — see repro.serving.admission).

Clients may attach a per-request deadline (``Client.get_score(q, a,
deadline_s=...)``, wire protocol v2); v1 clients without deadlines keep
working. For throughput-vs-tail-latency curves under open-loop Poisson
load, use ``python -m benchmarks.run --table loadgen --json out.json``.

The pipeline section runs the same stage cascade two ways:

  sequential — ``MultiStageRanker.run(query)`` per query: every query pays
      its own BM25 dispatch and scorer call, and the rerank stage re-encodes
      the query once per candidate;
  batched    — ``BatchedMultiStageRanker.run_batch(queries)``: one coalesced
      BM25 scoring call for the whole batch, one LRU-cached featurization
      pass (each query/sentence encoded once), and bucketed cross-query
      scorer batches — identical rankings, reported with the measured
      speedup.
"""
import argparse
import time

import numpy as np

from repro.launch.world import build_world, percentile_stats
from repro.core import backends as BK
from repro.core import pipeline as PL
from repro.core import service as SV
from repro.core.batch_pipeline import BatchedMultiStageRanker


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--backend", default="aot", choices=BK.BACKENDS)
    ap.add_argument("--server", default="simple",
                    choices=["simple", "threadpool"])
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="least_outstanding")
    ap.add_argument("--max-queue", type=int, default=512)
    args = ap.parse_args()

    print("== building world (corpus, index, trained reranker) ==")
    cfg, params, corpus, tok, index, pairs = build_world(train_steps=80)

    print(f"== serving through RPC ({args.backend} backend, "
          f"{args.server} server) ==")
    scorer = BK.make_scorer(args.backend, params, cfg, buckets=(1, 8, 64, 256))
    pool = None
    if args.server == "simple":
        handler = SV.QuestionAnsweringHandler(scorer, tok, corpus.idf,
                                              cfg.max_len)
        srv = SV.SimpleServer(handler).start_background()
    else:
        from repro.serving.admission import AdmissionController
        from repro.serving.cluster import ReplicaPool
        pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                                 n_replicas=args.replicas,
                                 buckets=(1, 8, 64, 256), policy=args.policy)
        admission = (AdmissionController(args.max_queue)
                     if args.max_queue > 0 else None)
        srv = SV.ThreadPoolServer(pool,
                                  admission=admission).start_background()
    client = SV.Client(srv.address)

    reqs = []
    for qi, di, si, _ in (pairs * 4)[: args.requests]:
        reqs.append((corpus.questions[qi], corpus.documents[di][si]))
    client.get_score(*reqs[0])  # warm the compiled entry

    lats = []
    t0 = time.perf_counter()
    for q, a in reqs:
        t1 = time.perf_counter()
        client.get_score(q, a)
        lats.append(time.perf_counter() - t1)
    dt = time.perf_counter() - t0
    p50, p99 = percentile_stats(lats)
    print(f"  {len(reqs)} requests  QPS={len(reqs)/dt:8.1f}  "
          f"p50={p50*1e3:.2f}ms  p99={p99*1e3:.2f}ms")

    # batched requests through the same service
    t0 = time.perf_counter()
    client.get_score_batch(reqs[:64])
    bdt = time.perf_counter() - t0
    print(f"  batched(64)          QPS={64/bdt:8.1f}")
    client.close()
    srv.stop()
    if pool is not None:
        print("  cluster stats: " + " ".join(
            f"{k}={v:.1f}" for k, v in sorted(pool.stats().items())
            if k.endswith("_requests") or k == "outstanding_rows"))
        pool.stop()

    print("\n== multi-stage pipeline answers ==")
    stages_list = [
        PL.RetrievalStage(index, corpus.documents, tok, h=10),
        PL.CutoffStage(margin=3.0),
        PL.RerankStage(scorer, tok, corpus.idf, cfg.max_len, k=3),
    ]
    ranker = PL.MultiStageRanker(stages_list)
    for q in corpus.questions[:3]:
        final, trace = ranker.run(q)
        stages = " -> ".join(f"{t.name}({len(t.candidates)}, "
                             f"{t.latency_s*1e3:.1f}ms)" for t in trace)
        print(f"  Q: {q}")
        print(f"     {stages}")
        if final:
            print(f"     A: {final[0].text}  (score {final[0].score:.3f})")

    print("\n== batched vs sequential pipeline (32-query batch) ==")
    queries = corpus.questions[:32]
    warm = corpus.questions[32:]    # disjoint warm-up set: the measured
    batched = BatchedMultiStageRanker(stages_list)   # queries/pairs stay cold
    ranker.run(warm[0])
    batched.run_batch(warm)
    t0 = time.perf_counter()
    for q in queries:
        ranker.run(q)
    seq_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    results = batched.run_batch(queries)
    bat_dt = time.perf_counter() - t0
    assert len(results) == len(queries)
    cache = batched.cache_stats()
    print(f"  sequential  {len(queries)/seq_dt:8.1f} q/s")
    print(f"  batched     {len(queries)/bat_dt:8.1f} q/s  "
          f"(speedup {seq_dt/bat_dt:.2f}x, feat-cache hit rate "
          f"{cache['feat_cache_hit_rate']:.0%})")


if __name__ == "__main__":
    main()
