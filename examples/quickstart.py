"""Quickstart: train the paper's CNN reranker on synthetic TrecQA-style data,
then score the same pairs through every integration backend.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw, warmup_cosine_schedule
from repro.training.train_loop import Trainer


def main():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=80, n_questions=60, seed=0)
    tok = HashingTokenizer(cfg.vocab_size)

    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    trainer = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg),
                      adamw(warmup_cosine_schedule(3e-3, 10, 300)), params)

    def stream():
        epoch = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=epoch)
            epoch += 1

    print("== training ==")
    trainer.run(stream(), max_steps=100, log_every=25)

    print("\n== integration backends (same weights, same scores) ==")
    dev = QA.make_batch(corpus, tok, cfg.max_len, corpus.pairs[:16])
    for backend in BK.BACKENDS:
        scorer = BK.make_scorer(backend, trainer.params, cfg, buckets=(16, 64))
        s = scorer(dev["q_tok"], dev["a_tok"], dev["feats"])
        acc = float(np.mean((s > 0.5) == (dev["label"] > 0.5)))
        print(f"  {backend:9s} score[0]={s[0]:.6f}  acc={acc:.2f}")


if __name__ == "__main__":
    main()
