"""Quickstart: train the paper's CNN reranker on synthetic TrecQA-style data,
score the same pairs through every integration backend, then compose a
multi-stage ranking pipeline with the declarative algebra and run it under
two execution plans.

  PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import backends as BK
from repro.core import bm25 as BM
from repro.core import ops
from repro.core.plan import PlanContext, plan, verify_plans
from repro.data import qa as QA
from repro.data.tokenizer import HashingTokenizer
from repro.models import sm_cnn
from repro.training.optimizer import adamw, warmup_cosine_schedule
from repro.training.train_loop import Trainer


def main():
    cfg = reduced(get_config("sm-cnn"))
    corpus = QA.generate_corpus(n_docs=80, n_questions=60, seed=0)
    tok = HashingTokenizer(cfg.vocab_size)

    params = sm_cnn.init_sm_cnn(jax.random.PRNGKey(0), cfg)
    trainer = Trainer(functools.partial(sm_cnn.loss_fn, cfg=cfg),
                      adamw(warmup_cosine_schedule(3e-3, 10, 300)), params)

    def stream():
        epoch = 0
        while True:
            yield from QA.pair_batches(corpus, tok, cfg.max_len, 64, seed=epoch)
            epoch += 1

    print("== training ==")
    trainer.run(stream(), max_steps=100, log_every=25)

    print("\n== integration backends (same weights, same scores) ==")
    dev = QA.make_batch(corpus, tok, cfg.max_len, corpus.pairs[:16])
    for backend in BK.BACKENDS:
        scorer = BK.make_scorer(backend, trainer.params, cfg, buckets=(16, 64))
        s = scorer(dev["q_tok"], dev["a_tok"], dev["feats"])
        acc = float(np.mean((s > 0.5) == (dev["label"] > 0.5)))
        print(f"  {backend:9s} score[0]={s[0]:.6f}  acc={acc:.2f}")

    print("\n== one pipeline, many execution plans ==")
    # The pipeline is a pure description; plan() picks the execution
    # strategy. See examples/compose_pipelines.py for the full tour.
    index = BM.build_index([tok.encode(" ".join(d))
                            for d in corpus.documents], cfg.vocab_size)
    ctx = PlanContext.from_world(cfg, trainer.params, corpus, tok, index)
    pipeline = ops.Retrieve(h=10) >> ops.Rerank("jit") % 3
    print(f"  pipeline: {pipeline!r}")
    plans = [plan(pipeline, t, ctx) for t in ("local", "batched")]
    for p in plans:
        print(f"  {p.describe()}")
    verify_plans(plans, corpus.questions[:8])
    final, _ = plans[1].run(corpus.questions[0])
    print(f"  plans agree; Q: {corpus.questions[0]}")
    print(f"               A: {final[0].text}  (score {final[0].score:.3f})")


if __name__ == "__main__":
    main()
