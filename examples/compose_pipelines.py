"""Worked tour of the declarative pipeline algebra (repro.core.ops) and the
planner (repro.core.plan): compose ONE ranking pipeline, lower it to local,
batched, and remote execution plans, and check they produce the same
rankings.

  PYTHONPATH=src python examples/compose_pipelines.py

The algebra, in one line:

  Retrieve(idx, h=20) >> (Rerank("jit") | Rerank("numpy")) % 10

  >>  compose stages          |  equal-weight score fusion
  %   rank cutoff sugar       Fuse((a, b), (w1, w2)) for custom weights
"""
import pickle
import time

from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext, plan, verify_plans
from repro.launch.world import build_world


def main():
    print("== building world (corpus, index, trained reranker) ==")
    cfg, params, corpus, tok, index, _ = build_world(train_steps=60)

    # ------------------------------------------------------------------
    # 1. A pipeline is a value: build it, print it, pickle it.
    # ------------------------------------------------------------------
    pipeline = (ops.Retrieve(index, h=20)
                >> (ops.Rerank("jit") | ops.Rerank("numpy")) % 10)
    print("\n== the pipeline is a pure description ==")
    print(f"  {pipeline!r}")
    roundtrip = pickle.loads(pickle.dumps(pipeline))
    print(f"  picklable: {repr(roundtrip) == repr(pipeline)}")

    # Normalization folds cutoffs before lowering:
    messy = (ops.Retrieve(index, h=20) >> ops.Cutoff(50) >> ops.Cutoff(30)
             >> ops.Rerank("jit") % 10 % 5)
    print(f"  normalize({messy!r})\n    -> {ops.normalize(messy)!r}")

    # ------------------------------------------------------------------
    # 2. One context binds the world; three targets execute the pipeline.
    # ------------------------------------------------------------------
    ctx = PlanContext.from_world(cfg, params, corpus, tok, index)

    # Stand up a real RPC server for the remote plan: rerank stages will
    # ship their (query, sentence) pairs through a service.Client with a
    # shed-retry budget. Fused stages may hit per-backend endpoints — here
    # both specs map to the same server (it scores with the jit backend, so
    # for the fused pipeline we rerank remotely with a single spec below).
    handler = SV.QuestionAnsweringHandler(ctx.scorer_for("jit", 200), tok,
                                          corpus.idf, cfg.max_len)
    srv = SV.SimpleServer(handler).start_background()

    single = ops.Retrieve(index, h=20) >> ops.Rerank("jit") % 10
    plans = [plan(single, "local", ctx),
             plan(single, "batched", ctx),
             plan(single, "remote", ctx=ctx, remote=srv.address)]
    print("\n== one pipeline, three execution plans ==")
    for p in plans:
        print(f"  {p.describe()}")
    queries = corpus.questions[:16]
    verify_plans(plans, queries)
    print(f"  identical rankings on {len(queries)} queries across all plans")

    for p in plans:
        p.run_many(queries)           # warm compiled entries + caches
        t0 = time.perf_counter()
        p.run_many(queries)
        dt = time.perf_counter() - t0
        print(f"  {p.target:8s} {len(queries) / dt:8.1f} q/s")
    srv.stop()

    # ------------------------------------------------------------------
    # 3. Fusion: interpolate two integration backends' scores.
    # ------------------------------------------------------------------
    print("\n== score fusion ==")
    fused = plan(pipeline, "batched", ctx)
    print(f"  {fused.describe()}")
    weighted = plan(ops.Retrieve(index, h=20)
                    >> ops.Fuse((ops.Rerank("jit"), ops.Rerank("numpy")),
                                (0.7, 0.3)) % 10,
                    "batched", ctx)
    q = queries[0]
    (eq_cands, _), (w_cands, _) = fused.run(q), weighted.run(q)
    print(f"  Q: {q}")
    print(f"  0.5/0.5 top answer: {eq_cands[0].text!r} "
          f"(score {eq_cands[0].score:.3f})")
    print(f"  0.7/0.3 top answer: {w_cands[0].text!r} "
          f"(score {w_cands[0].score:.3f})")


if __name__ == "__main__":
    main()
