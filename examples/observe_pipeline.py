"""Observability tour: follow one query from client to scorer and back.

Stands up the canonical cascade as a live service, fires queries at it,
then answers the three operator questions the telemetry fabric exists for:

  1. WHERE DID THE TIME GO — one request's span tree, from the client's
     ``client.rank_batch`` span down through server dispatch, admission,
     plan stages, micro-batcher queue-wait vs compute, and the scorer
     call, printed as an indented tree with per-span latency.
  2. WHAT IS THE FLEET DOING — the process-wide MetricsRegistry snapshot
     (Prometheus-style flattened keys: counters with labels, histogram
     buckets), the same payload a v5 MSG_STATS control frame returns to a
     fabric supervisor.
  3. CAN I LOOK AT IT PROPERLY — the collected spans exported as Chrome
     trace-event JSON; load the file in https://ui.perfetto.dev or
     chrome://tracing and every lane/nesting matches the printed tree.

  PYTHONPATH=src python examples/observe_pipeline.py
  PYTHONPATH=src python examples/observe_pipeline.py --queries 12 \\
      --trace-out pipeline_trace.json

The server's rerank dispatches into an in-process ``ReplicaPool``
(``target="remote"``), so the demo exercises the full instrumented path a
fabric worker runs — including the batcher queue-wait/compute split that
MSG_STATS aggregation reports per worker.
"""
import argparse

from repro.launch.world import build_world
from repro.core import backends as BK
from repro.core import ops
from repro.core import service as SV
from repro.core.plan import PlanContext
from repro.serving import telemetry
from repro.serving.cluster import ReplicaPool
from repro.serving.engine import PipelineEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="numpy", choices=BK.BACKENDS)
    ap.add_argument("--queries", type=int, default=8)
    ap.add_argument("--trace-out", default="pipeline_trace.json",
                    metavar="PATH", help="Chrome trace-event JSON output "
                    "(open in Perfetto); empty string disables")
    args = ap.parse_args()

    print("== building world (corpus, index, trained reranker) ==")
    cfg, params, corpus, tok, index, _ = build_world(train_steps=30)

    print(f"== serving the canonical cascade ({args.backend}, rerank via "
          f"in-process replica pool) ==")
    pipeline = (ops.Retrieve(h=10) >> ops.DynamicCutoff(margin=3.0)
                >> ops.Rerank(args.backend, k=3))
    pool = ReplicaPool.build(args.backend, params, cfg, tok, corpus.idf,
                             n_replicas=2, buckets=(1, 8, 64, 256))
    engine = PipelineEngine(
        pipeline,
        PlanContext.from_world(cfg, params, corpus, tok, index,
                               buckets=(1, 8, 64, 256), remote=pool),
        target="remote")
    srv = SV.ThreadPoolServer(engine).start_background()
    print(f"  {engine.describe()}")

    queries = corpus.questions[: args.queries]
    telemetry.reset_all()           # the report covers only this traffic
    with SV.Client(srv.address) as client:
        for q in queries:
            client.rank_batch([q])
        # The client span is the trace root: its context crossed the wire
        # (v5 FLAG_TRACE), so the server-side spans join the same tree.
        spans = telemetry.get_tracer().finished()
        last_trace = spans[-1].trace_id

        print(f"\n== span tree: last query ({queries[-1]!r}) ==")
        print(telemetry.format_span_tree(spans, trace_id=last_trace))

        print("\n== per-stage breakdown over all "
              f"{len(queries)} queries ==")
        agg = telemetry.stage_breakdown(spans)
        width = max(len(n) for n in agg)
        for name in sorted(agg, key=lambda n: -agg[n]["total_ms"]):
            a = agg[name]
            print(f"  {name:<{width}}  n={int(a['count']):4d}  "
                  f"mean={a['mean_ms']:8.3f}ms  "
                  f"total={a['total_ms']:8.1f}ms")

        print("\n== metrics registry snapshot (MSG_STATS payload) ==")
        snap = telemetry.get_registry().snapshot()
        for key in sorted(snap):
            if "_bucket{" in key:   # elide per-bucket rows for readability
                continue
            print(f"  {key} = {snap[key]:g}")
        waits = [k for k in snap if k.startswith("batcher_queue_wait_ms")]
        print(f"  (+ {sum(1 for k in snap if '_bucket{' in k)} histogram "
              f"bucket keys, e.g. {len(waits)} for batcher queue-wait)")

    if args.trace_out:
        n = telemetry.export_chrome_trace(args.trace_out, spans)
        print(f"\n== wrote {n} trace events to {args.trace_out} ==")
        print("   open in https://ui.perfetto.dev or chrome://tracing")

    srv.stop()
    pool.stop()


if __name__ == "__main__":
    main()
